//! Incremental generation session over a quantized [`Engine`]: one token
//! per step, KV entries coded on insertion into the paged pool through
//! each layer's own [`crate::kvpool::KvLaneCodec`] (fp32 / uniform /
//! nested lanes — the pool is the sole KV backend), attention scored
//! against the coded keys — the paper's memory-bound generation path.
//!
//! Sessions can share an `Arc<KvPool>` ([`GenSession::new_in_pool`]):
//! prefill then maps any cached token prefix straight from the pool
//! (zero forward/quantization work for matched positions) and decode
//! steps publish completed pages back to the pool's prefix index.

use crate::kvpool::{KvPool, PoolConfig, SessionKv};
use crate::model::engine::Engine;
use crate::model::forward::{gelu, rmsnorm, softmax_inplace};
use crate::util::linalg::Mat;
use crate::util::Rng;
use std::sync::Arc;

/// A single-stream generation session.
pub struct GenSession<'a> {
    eng: &'a Engine,
    cache: SessionKv,
    pos: usize,
}

impl<'a> GenSession<'a> {
    /// A session with a private single-owner pool carrying the engine's
    /// per-layer lane codecs (an all-fp model gets an all-`Fp32`-lane
    /// pool — there is no separate fp cache path).
    pub fn new(eng: &'a Engine) -> Self {
        GenSession {
            eng,
            cache: SessionKv::new(eng.kv_pool(PoolConfig::default())),
            pos: 0,
        }
    }

    /// A session drawing its KV pages from a shared pool — the
    /// multi-session serving path (prefix sharing, byte budget, LRU
    /// eviction all happen in the pool).
    pub fn new_in_pool(eng: &'a Engine, pool: &Arc<KvPool>) -> Self {
        GenSession {
            eng,
            cache: SessionKv::new(pool.clone()),
            pos: 0,
        }
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    pub fn kv_bytes(&self) -> usize {
        self.cache.payload_bytes()
    }

    /// Feed one token, get logits for the next.
    pub fn step(&mut self, token: i32) -> Vec<f32> {
        let eng = self.eng;
        let cfg = &eng.cfg;
        let d = cfg.d_model;
        let dh = cfg.d_head();
        assert!(self.pos < cfg.ctx, "context overflow");

        let mut x = vec![0f32; d];
        let emb = eng.tok_emb.row(token as usize);
        let pos_emb = eng.pos_emb.row(self.pos);
        for i in 0..d {
            x[i] = emb[i] + pos_emb[i];
        }

        let mut normed = vec![0f32; d];
        let mut scores: Vec<f32> = Vec::new();
        for (li, l) in eng.layers.iter().enumerate() {
            rmsnorm(&x, &l.ln1, &mut normed);
            let xm = Mat::from_vec(1, d, normed.clone());
            let q = l.wq.forward(&xm);
            let k = l.wk.forward(&xm);
            let v = l.wv.forward(&xm);
            let mut att_out = vec![0f32; d];
            for h in 0..cfg.n_head {
                let mut kh = k.row(0)[h * dh..(h + 1) * dh].to_vec();
                let mut vh = v.row(0)[h * dh..(h + 1) * dh].to_vec();
                let mut qh = q.row(0)[h * dh..(h + 1) * dh].to_vec();
                if let Some(r) = &l.head_rot {
                    r.apply(&mut kh);
                    r.apply(&mut vh);
                    r.apply(&mut qh);
                }
                self.cache.append(li, h, &kh, &vh);
                self.cache.scores(li, h, &qh, &mut scores);
                let scale = 1.0 / (dh as f32).sqrt();
                for s in scores.iter_mut() {
                    *s *= scale;
                }
                softmax_inplace(&mut scores);
                // streaming value-weighted sum off the coded values —
                // no per-position dequantize buffer on the decode path
                let oh = &mut att_out[h * dh..(h + 1) * dh];
                self.cache.weighted_value_sum(li, h, &scores, oh);
                if let Some(r) = &l.head_rot {
                    r.apply_t(oh);
                }
            }
            let att = l.wo.forward(&Mat::from_vec(1, d, att_out));
            for i in 0..d {
                x[i] += att.row(0)[i];
            }
            rmsnorm(&x, &l.ln2, &mut normed);
            let mut h_mid = l.w_up.forward(&Mat::from_vec(1, d, normed.clone()));
            for v in h_mid.data.iter_mut() {
                *v = gelu(*v);
            }
            let down = l.w_down.forward(&h_mid);
            for i in 0..d {
                x[i] += down.row(0)[i];
            }
        }
        // the position is complete on every (layer, head) lane: publish
        // it (freezes + registers pages at page boundaries)
        self.cache.note_token(token);
        rmsnorm(&x, &eng.final_norm, &mut normed);
        let logits = eng.head.forward(&Mat::from_vec(1, d, normed.clone()));
        self.pos += 1;
        logits.data
    }

    /// Prefill a prompt: map the longest pool-cached prefix (at most
    /// `prompt.len()-1` positions — the final token is always recomputed
    /// so its logits exist), then step the remainder. Returns the logits
    /// after the last prompt token (zeros for an empty prompt).
    pub fn prefill(&mut self, prompt: &[i32]) -> Vec<f32> {
        assert_eq!(self.pos, 0, "prefill on a fresh session only");
        let matched = self.cache.match_prefix(prompt);
        self.pos = matched;
        let mut logits = vec![0f32; self.eng.cfg.vocab];
        for &t in &prompt[matched..] {
            logits = self.step(t);
        }
        logits
    }

    /// Greedy argmax sampling.
    pub fn greedy(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    /// Temperature sampling.
    pub fn sample(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
        if temp <= 0.0 {
            return Self::greedy(logits);
        }
        let mut probs: Vec<f32> = logits.iter().map(|&v| v / temp).collect();
        softmax_inplace(&mut probs);
        let r = rng.f32();
        let mut acc = 0f32;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc {
                return i as i32;
            }
        }
        probs.len() as i32 - 1
    }

    /// Prefill a prompt (prefix-served from the pool when shared), then
    /// generate `n_new` tokens greedily. Returns the generated tokens.
    ///
    /// On a session that has already consumed tokens, `prompt` extends
    /// the stream; with an empty `prompt` the first greedy pick seeds
    /// from zero logits (token 0) since the previous step's logits are
    /// owned by the caller — pass them through [`Self::step`] yourself
    /// for logits-continuous continuation.
    pub fn generate(&mut self, prompt: &[i32], n_new: usize) -> Vec<i32> {
        let mut logits = if self.pos == 0 {
            self.prefill(prompt)
        } else {
            // continuing an existing stream: prefix mapping only applies
            // to fresh sessions, so step any extra prompt tokens directly
            let mut logits = vec![0f32; self.eng.cfg.vocab];
            for &t in prompt {
                logits = self.step(t);
            }
            logits
        };
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.eng.cfg.ctx {
                break;
            }
            let next = Self::greedy(&logits);
            out.push(next);
            logits = self.step(next);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Method, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};

    fn load_tiny() -> Option<ModelWeights> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        p.exists().then(|| ModelWeights::load(&p).unwrap())
    }

    #[test]
    fn incremental_matches_window_forward_fp() {
        // step-by-step logits must equal the full-window forward logits
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::Fp,
                ..Default::default()
            },
        );
        let toks: Vec<i32> = w.val_tokens[..16].to_vec();
        let full = eng.forward_window(&toks);
        let mut sess = GenSession::new(&eng);
        for (t, &tok) in toks.iter().enumerate() {
            let logits = sess.step(tok);
            for v in 0..w.cfg.vocab {
                assert!(
                    (logits[v] - full[(t, v)]).abs() < 1e-3,
                    "t={t} v={v}: {} vs {}",
                    logits[v],
                    full[(t, v)]
                );
            }
        }
    }

    #[test]
    fn generates_plausible_text_quantized() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 2,
                ..Default::default()
            },
        );
        let mut sess = GenSession::new(&eng);
        let prompt: Vec<i32> = w.val_tokens[..8].to_vec();
        let out = sess.generate(&prompt, 24);
        assert_eq!(out.len(), 24);
        assert!(out.iter().all(|&t| (t as usize) < w.cfg.vocab));
        // quantized KV cache must actually be in coded form (small)
        let bytes = sess.kv_bytes();
        let fp_bytes = 2 * sess.position() * w.cfg.d_model * 4 * w.cfg.n_layer / w.cfg.n_head
            * w.cfg.n_head;
        assert!(bytes < fp_bytes / 3, "kv {bytes} vs fp {fp_bytes}");
    }

    #[test]
    fn pooled_prefill_matches_cold_session_bitwise() {
        // Two sessions sharing a ≥64-token prompt through one pool: the
        // second must (a) map shared pages instead of re-quantizing,
        // (b) produce bit-identical logits to the cold path, (c) use
        // strictly less than 2× one session's pool bytes.
        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 96,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0xBEEF);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        );
        let pool = eng.kv_pool(PoolConfig::default());
        let vocab = cfg.vocab as i32;
        let prompt: Vec<i32> = (0..64).map(|i| (i * 7 % vocab + i) % vocab).collect();

        let mut a = GenSession::new_in_pool(&eng, &pool);
        let la = a.prefill(&prompt);
        let bytes_one = pool.stats().bytes_in_use;
        assert!(pool.stats().prefix_hit_tokens == 0);

        let mut b = GenSession::new_in_pool(&eng, &pool);
        let lb = b.prefill(&prompt);
        assert_eq!(b.position(), prompt.len());
        let st = pool.stats();
        assert!(
            st.prefix_hit_tokens >= 48,
            "expected ≥3 shared pages, stats {st:?}"
        );
        assert!(
            st.bytes_in_use < 2 * bytes_one,
            "sharing saved nothing: {} vs 2×{}",
            st.bytes_in_use,
            bytes_one
        );
        assert_eq!(la.len(), lb.len());
        for (i, (x, y)) in la.iter().zip(&lb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "logit {i} diverges between shared and cold prefill: {x} vs {y}"
            );
        }
        // and greedy decode stays bitwise-identical step by step (each
        // step reads the caches — shared pages vs privately quantized)
        let (mut ga, mut gb) = (la, lb);
        for s in 0..8 {
            let (ta, tb) = (GenSession::greedy(&ga), GenSession::greedy(&gb));
            assert_eq!(ta, tb, "greedy token diverges at step {s}");
            ga = a.step(ta);
            gb = b.step(tb);
            for (i, (x, y)) in ga.iter().zip(&gb).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "step {s} logit {i} diverges");
            }
        }
    }

    #[test]
    fn per_layer_kv_quantizers_are_used() {
        // the engine calibrates a quantizer pair per layer; the pool
        // must carry each layer's own pair, not layer 0's for all
        let cfg = crate::model::ModelConfig {
            vocab: 48,
            ctx: 32,
            d_model: 32,
            n_layer: 3,
            n_head: 2,
            d_ff: 64,
        };
        let w = ModelWeights::synthetic(cfg, 0xA11);
        let eng = Engine::build(
            &w,
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        );
        let pool = eng.kv_pool(PoolConfig::default());
        for (li, l) in eng.layers.iter().enumerate() {
            let (k_nq, v_nq) = match &l.kv {
                crate::model::engine::KvLaneCodec::Nested { k, v } => (k, v),
                _ => panic!("layer {li} must carry a nested KV pair"),
            };
            match pool.lane(li) {
                crate::model::engine::KvLaneCodec::Nested { k, v } => {
                    assert_eq!(k.betas, k_nq.betas, "layer {li} key quantizer mismatch");
                    assert_eq!(v.betas, v_nq.betas, "layer {li} value quantizer mismatch");
                }
                other => panic!("layer {li} pool lane must be nested, got {other:?}"),
            }
        }
    }
}
