//! Incremental generation session over a quantized [`Engine`]: one token
//! per step, KV entries quantized on insertion (coded storage via
//! [`KvCache`]), attention scored against decoded keys — the paper's
//! memory-bound generation path.

use crate::kvcache::KvCache;
use crate::model::engine::Engine;
use crate::model::forward::{gelu, rmsnorm, softmax_inplace};
use crate::util::linalg::Mat;
use crate::util::Rng;

/// A single-stream generation session.
pub struct GenSession<'a> {
    eng: &'a Engine,
    cache: KvCache,
    pos: usize,
}

impl<'a> GenSession<'a> {
    pub fn new(eng: &'a Engine) -> Self {
        let cfg = &eng.cfg;
        let cache = if eng.opts.regime.quantizes_kv() {
            // per-layer quantizers exist; the cache API takes one pair —
            // use layer 0's calibrated quantizers as the shared dictionary
            // (per-layer dictionaries differ marginally; layer-indexed
            // caches would use `eng.layers[l].k_nq` directly).
            let l0 = &eng.layers[0];
            match (&l0.k_nq, &l0.v_nq) {
                (Some(k), Some(v)) => KvCache::new_nest(cfg.n_layer, cfg.n_head, k.clone(), v.clone()),
                _ => KvCache::new_fp(cfg.n_layer, cfg.n_head),
            }
        } else {
            KvCache::new_fp(cfg.n_layer, cfg.n_head)
        };
        GenSession { eng, cache, pos: 0 }
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    pub fn kv_bytes(&self) -> usize {
        self.cache.payload_bytes()
    }

    /// Feed one token, get logits for the next.
    pub fn step(&mut self, token: i32) -> Vec<f32> {
        let eng = self.eng;
        let cfg = &eng.cfg;
        let d = cfg.d_model;
        let dh = cfg.d_head();
        let qa = eng.opts.regime.quantizes_acts();
        let ub = (!eng.opts.method.is_nested()).then_some(eng.opts.uniform_bits);
        assert!(self.pos < cfg.ctx, "context overflow");

        let mut x = vec![0f32; d];
        let emb = eng.tok_emb.row(token as usize);
        let pos_emb = eng.pos_emb.row(self.pos);
        for i in 0..d {
            x[i] = emb[i] + pos_emb[i];
        }

        let mut normed = vec![0f32; d];
        let mut scores: Vec<f32> = Vec::new();
        for (li, l) in eng.layers.iter().enumerate() {
            rmsnorm(&x, &l.ln1, &mut normed);
            let xm = Mat::from_vec(1, d, normed.clone());
            let q = l.wq.forward(&xm, qa, ub);
            let k = l.wk.forward(&xm, qa, ub);
            let v = l.wv.forward(&xm, qa, ub);
            let mut att_out = vec![0f32; d];
            for h in 0..cfg.n_head {
                let mut kh = k.row(0)[h * dh..(h + 1) * dh].to_vec();
                let mut vh = v.row(0)[h * dh..(h + 1) * dh].to_vec();
                let mut qh = q.row(0)[h * dh..(h + 1) * dh].to_vec();
                if let Some(r) = &l.head_rot {
                    r.apply(&mut kh);
                    r.apply(&mut vh);
                    r.apply(&mut qh);
                }
                self.cache.append(li, h, &kh, &vh);
                self.cache.scores(li, h, &qh, &mut scores);
                let scale = 1.0 / (dh as f32).sqrt();
                for s in scores.iter_mut() {
                    *s *= scale;
                }
                softmax_inplace(&mut scores);
                let mut oh = vec![0f32; dh];
                for (t, &p) in scores.iter().enumerate() {
                    let vt = self.cache.value(li, h, t);
                    for i in 0..dh {
                        oh[i] += p * vt[i];
                    }
                }
                if let Some(r) = &l.head_rot {
                    r.apply_t(&mut oh);
                }
                att_out[h * dh..(h + 1) * dh].copy_from_slice(&oh);
            }
            let att = l
                .wo
                .forward(&Mat::from_vec(1, d, att_out), qa, ub);
            for i in 0..d {
                x[i] += att.row(0)[i];
            }
            rmsnorm(&x, &l.ln2, &mut normed);
            let mut h_mid = l
                .w_up
                .forward(&Mat::from_vec(1, d, normed.clone()), qa, ub);
            for v in h_mid.data.iter_mut() {
                *v = gelu(*v);
            }
            let down = l.w_down.forward(&h_mid, qa, ub);
            for i in 0..d {
                x[i] += down.row(0)[i];
            }
        }
        rmsnorm(&x, &eng.final_norm, &mut normed);
        let logits = eng
            .head
            .forward(&Mat::from_vec(1, d, normed.clone()), qa, ub);
        self.pos += 1;
        logits.data
    }

    /// Greedy argmax sampling.
    pub fn greedy(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    /// Temperature sampling.
    pub fn sample(logits: &[f32], temp: f32, rng: &mut Rng) -> i32 {
        if temp <= 0.0 {
            return Self::greedy(logits);
        }
        let mut probs: Vec<f32> = logits.iter().map(|&v| v / temp).collect();
        softmax_inplace(&mut probs);
        let r = rng.f32();
        let mut acc = 0f32;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc {
                return i as i32;
            }
        }
        probs.len() as i32 - 1
    }

    /// Prefill a prompt, then generate `n_new` tokens greedily. Returns
    /// the generated tokens.
    pub fn generate(&mut self, prompt: &[i32], n_new: usize) -> Vec<i32> {
        let mut logits = vec![0f32; self.eng.cfg.vocab];
        for &t in prompt {
            logits = self.step(t);
        }
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if self.pos >= self.eng.cfg.ctx {
                break;
            }
            let next = Self::greedy(&logits);
            out.push(next);
            logits = self.step(next);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::engine::{EngineOptions, Regime};
    use crate::model::weights::{artifact_path, ModelWeights};

    fn load_tiny() -> Option<ModelWeights> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = artifact_path(&dir, "tiny");
        p.exists().then(|| ModelWeights::load(&p).unwrap())
    }

    #[test]
    fn incremental_matches_window_forward_fp() {
        // step-by-step logits must equal the full-window forward logits
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::Fp,
                ..Default::default()
            },
        );
        let toks: Vec<i32> = w.val_tokens[..16].to_vec();
        let full = eng.forward_window(&toks);
        let mut sess = GenSession::new(&eng);
        for (t, &tok) in toks.iter().enumerate() {
            let logits = sess.step(tok);
            for v in 0..w.cfg.vocab {
                assert!(
                    (logits[v] - full[(t, v)]).abs() < 1e-3,
                    "t={t} v={v}: {} vs {}",
                    logits[v],
                    full[(t, v)]
                );
            }
        }
    }

    #[test]
    fn generates_plausible_text_quantized() {
        let Some(w) = load_tiny() else { return };
        let eng = Engine::build(
            &w,
            EngineOptions {
                regime: Regime::WKv,
                calib_windows: 2,
                ..Default::default()
            },
        );
        let mut sess = GenSession::new(&eng);
        let prompt: Vec<i32> = w.val_tokens[..8].to_vec();
        let out = sess.generate(&prompt, 24);
        assert_eq!(out.len(), 24);
        assert!(out.iter().all(|&t| (t as usize) < w.cfg.vocab));
        // quantized KV cache must actually be in coded form (small)
        let bytes = sess.kv_bytes();
        let fp_bytes = 2 * sess.position() * w.cfg.d_model * 4 * w.cfg.n_layer / w.cfg.n_head
            * w.cfg.n_head;
        assert!(bytes < fp_bytes / 3, "kv {bytes} vs fp {fp_bytes}");
    }
}
