//! Dynamic programming for the optimal set of scaling coefficients β
//! (paper §4.4, Algorithm 6, Appendix F).
//!
//! Given samples of 8-vectors from the distribution to be quantized, a
//! universe of candidate βs, and a budget k, choose the subset of size k
//! minimizing total reconstruction MSE under the First-β strategy (use the
//! smallest non-overloading β). The largest chosen β must overload on no
//! sample (plus a safety margin for unseen data, Appendix G).

use super::e8::D;
use super::voronoi::VoronoiCodec;

/// Per-sample, per-β quantization outcome table.
pub struct BetaTable {
    /// mse[i][j] = reconstruction MSE of sample i at β_j
    pub mse: Vec<Vec<f64>>,
    /// overload[i][j]
    pub overload: Vec<Vec<bool>>,
    pub betas: Vec<f32>,
}

impl BetaTable {
    /// Build the table by quantizing every sample at every candidate β.
    pub fn build(codec: &VoronoiCodec, samples: &[[f32; D]], betas: &[f32]) -> Self {
        let mut mse = Vec::with_capacity(samples.len());
        let mut overload = Vec::with_capacity(samples.len());
        for v in samples {
            let mut row_mse = Vec::with_capacity(betas.len());
            let mut row_ov = Vec::with_capacity(betas.len());
            for &beta in betas {
                let inv = 1.0 / beta;
                let mut xs = [0f32; D];
                for i in 0..D {
                    xs[i] = v[i] * inv;
                }
                let (r, ov) = codec.encode_decode(&xs);
                let mut err = 0f64;
                for i in 0..D {
                    let d = (r[i] * beta - v[i]) as f64;
                    err += d * d;
                }
                row_mse.push(err);
                row_ov.push(ov);
            }
            mse.push(row_mse);
            overload.push(row_ov);
        }
        BetaTable {
            mse,
            overload,
            betas: betas.to_vec(),
        }
    }
}

/// Result of the β-selection DP.
#[derive(Clone, Debug)]
pub struct BetaSelection {
    /// chosen βs, ascending
    pub betas: Vec<f32>,
    /// total First-β MSE over the samples
    pub total_mse: f64,
    /// fraction of samples assigned to each chosen β (usage probabilities
    /// for the entropy term of the effective rate)
    pub usage: Vec<f64>,
}

/// Paper Algorithm 6. Picks k βs from the candidate universe minimizing
/// First-β MSE, requiring the largest chosen β to have zero overloads on
/// the samples. Returns `None` when even the largest candidate overloads.
pub fn optimal_betas(table: &BetaTable, k: usize) -> Option<BetaSelection> {
    let m = table.betas.len();
    let n = table.mse.len();
    assert!(k >= 1);
    if n == 0 || m == 0 {
        return None;
    }

    // cost[s][i] = Σ_p (overload[p][s] ∧ ¬overload[p][i]) · mse[p][i]
    // where s = 0 is the sentinel "no smaller β" (overloads everywhere).
    // We compute cost lazily inside the DP loops; to keep the complexity
    // at O(m²·(n/64)·k) we precompute per-β overload bitsets.
    let words = n.div_ceil(64);
    let mut ov_bits = vec![vec![0u64; words]; m + 1];
    ov_bits[0] = vec![!0u64; words]; // sentinel: everything overloads
    if n % 64 != 0 {
        ov_bits[0][words - 1] = (1u64 << (n % 64)) - 1;
    }
    for j in 0..m {
        for p in 0..n {
            if table.overload[p][j] {
                ov_bits[j + 1][p / 64] |= 1 << (p % 64);
            }
        }
    }

    let inf = f64::INFINITY;
    // dp[i][j]: min MSE covering all samples that do NOT overload at β_i
    // (1-based i), using β_i plus j-1 smaller βs. from[i][j] for traceback.
    let mut dp = vec![vec![inf; k + 1]; m + 1];
    let mut from = vec![vec![usize::MAX; k + 1]; m + 1];
    dp[0][0] = 0.0;

    for i in 1..=m {
        for j in 1..=k.min(i) {
            for s in 0..i {
                if dp[s][j - 1] == inf {
                    continue;
                }
                // samples that overload at β_s but not at β_i get β_i
                let mut cost = 0.0;
                for w in 0..words {
                    let mut bits = ov_bits[s][w] & !ov_bits[i][w];
                    while bits != 0 {
                        let p = w * 64 + bits.trailing_zeros() as usize;
                        cost += table.mse[p][i - 1];
                        bits &= bits - 1;
                    }
                }
                let cand = dp[s][j - 1] + cost;
                if cand < dp[i][j] {
                    dp[i][j] = cand;
                    from[i][j] = s;
                }
            }
        }
    }

    // The answer: best dp[i][j] (j ≤ k) over βs with no overloads at all.
    let mut best: Option<(usize, usize)> = None;
    for i in 1..=m {
        let clean = ov_bits[i].iter().all(|&w| w == 0);
        if !clean {
            continue;
        }
        for j in 1..=k.min(i) {
            if dp[i][j] < inf {
                match best {
                    Some((bi, bj)) if dp[bi][bj] <= dp[i][j] => {}
                    _ => best = Some((i, j)),
                }
            }
        }
    }
    let (mut i, mut j) = best?;
    let total_mse = dp[i][j];

    let mut chosen = Vec::new();
    while i != 0 {
        chosen.push(i - 1);
        let s = from[i][j];
        i = s;
        j -= 1;
    }
    chosen.reverse();
    let betas: Vec<f32> = chosen.iter().map(|&c| table.betas[c]).collect();

    // First-β usage probabilities over the samples.
    let mut usage = vec![0f64; betas.len()];
    for p in 0..n {
        for (t, &c) in chosen.iter().enumerate() {
            if !table.overload[p][c] {
                usage[t] += 1.0;
                break;
            }
        }
    }
    for u in usage.iter_mut() {
        *u /= n as f64;
    }

    Some(BetaSelection {
        betas,
        total_mse,
        usage,
    })
}

/// Convenience wrapper: sample 8-blocks from `data`, run the DP over a
/// default β universe (paper App. G: values 1..40 scaled by 1/q with
/// variable spacing), apply the overload safety margin, return chosen βs.
pub fn select_betas_for_data(
    codec: &VoronoiCodec,
    blocks: &[[f32; D]],
    k: usize,
    margin: f32,
) -> Vec<f32> {
    let q = codec.q as f32;
    let universe = default_beta_universe(q);
    let table = BetaTable::build(codec, blocks, &universe);
    match optimal_betas(&table, k) {
        Some(mut sel) => {
            // Appendix G: add a margin to the largest β to absorb unseen
            // outliers (margin is e.g. 3/q for weights, 4/q for activations).
            if let Some(last) = sel.betas.last_mut() {
                *last += margin;
            }
            sel.betas
        }
        None => {
            // Even the largest candidate overloads: fall back to a scaled
            // default ladder that always covers (relative to max norm).
            let max_norm = blocks
                .iter()
                .map(|b| b.iter().map(|&x| x * x).sum::<f32>().sqrt())
                .fold(0.0f32, f32::max);
            let top = max_norm / q + margin;
            (1..=k).map(|t| top * t as f32 / k as f32).collect()
        }
    }
}

/// Paper App. G universe: "values from 1 to 40 with spacing ranging from
/// 0.25 to 2", divided by q.
pub fn default_beta_universe(q: f32) -> Vec<f32> {
    let mut v = Vec::new();
    let mut x = 1.0f32;
    while x <= 40.0 {
        v.push(x / q);
        let step = if x < 8.0 {
            0.25
        } else if x < 16.0 {
            0.5
        } else if x < 24.0 {
            1.0
        } else {
            2.0
        };
        x += step;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn gaussian_blocks(n: usize, seed: u64) -> Vec<[f32; D]> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut b = [0f32; D];
                rng.fill_gauss(&mut b);
                b
            })
            .collect()
    }

    #[test]
    fn dp_picks_cover_with_no_overload() {
        let codec = VoronoiCodec::new(16);
        let blocks = gaussian_blocks(400, 401);
        let universe = default_beta_universe(16.0);
        let table = BetaTable::build(&codec, &blocks, &universe);
        let sel = optimal_betas(&table, 4).expect("selection exists");
        assert_eq!(sel.betas.len().min(4), sel.betas.len());
        assert!(!sel.betas.is_empty() && sel.betas.len() <= 4);
        // Largest β must not overload on any sample.
        let last = *sel.betas.last().unwrap();
        for b in &blocks {
            let mut xs = [0f32; D];
            for i in 0..D {
                xs[i] = b[i] / last;
            }
            let (_, ov) = codec.encode_decode(&xs);
            assert!(!ov, "chosen max β overloads");
        }
        // Usage sums to 1.
        let s: f64 = sel.usage.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn more_betas_never_hurt() {
        let codec = VoronoiCodec::new(16);
        let blocks = gaussian_blocks(300, 402);
        let universe = default_beta_universe(16.0);
        let table = BetaTable::build(&codec, &blocks, &universe);
        let mut last = f64::INFINITY;
        for k in [1usize, 2, 4, 8] {
            let sel = optimal_betas(&table, k).unwrap();
            assert!(
                sel.total_mse <= last + 1e-9,
                "k={k}: {} > {}",
                sel.total_mse,
                last
            );
            last = sel.total_mse;
        }
    }

    #[test]
    fn dp_is_optimal_vs_exhaustive_small() {
        // Small universe: compare DP against brute-force subset search.
        let codec = VoronoiCodec::new(8);
        let blocks = gaussian_blocks(80, 403);
        let universe: Vec<f32> = (2..10).map(|i| i as f32 / 8.0).collect();
        let table = BetaTable::build(&codec, &blocks, &universe);
        let k = 3;
        let dp_sel = optimal_betas(&table, k);

        // brute force over subsets of size ≤ k whose max β never overloads
        let m = universe.len();
        let n = blocks.len();
        let mut best: Option<(f64, Vec<usize>)> = None;
        for mask in 1u32..(1 << m) {
            if mask.count_ones() as usize > k {
                continue;
            }
            let subset: Vec<usize> = (0..m).filter(|&j| mask >> j & 1 == 1).collect();
            let max_j = *subset.last().unwrap();
            if (0..n).any(|p| table.overload[p][max_j]) {
                continue;
            }
            let mut total = 0.0;
            for p in 0..n {
                let j = subset
                    .iter()
                    .copied()
                    .find(|&j| !table.overload[p][j])
                    .unwrap();
                total += table.mse[p][j];
            }
            if best.as_ref().map_or(true, |(b, _)| total < *b) {
                best = Some((total, subset));
            }
        }
        match (dp_sel, best) {
            (Some(dp), Some((bf, _))) => {
                assert!(
                    (dp.total_mse - bf).abs() < 1e-9,
                    "dp {} vs brute force {bf}",
                    dp.total_mse
                );
            }
            (None, None) => {}
            (a, b) => panic!("dp={:?} bf={:?} disagree on feasibility", a.is_some(), b.is_some()),
        }
    }

    #[test]
    fn select_betas_margin_applied() {
        let codec = VoronoiCodec::new(14);
        let blocks = gaussian_blocks(200, 404);
        let margin = 3.0 / 14.0;
        let with_margin = select_betas_for_data(&codec, &blocks, 4, margin);
        let without = select_betas_for_data(&codec, &blocks, 4, 0.0);
        assert_eq!(with_margin.len(), without.len());
        let d = with_margin.last().unwrap() - without.last().unwrap();
        assert!((d - margin).abs() < 1e-6, "margin not applied: {d}");
    }

    #[test]
    fn universe_shape() {
        let u = default_beta_universe(14.0);
        assert!(u.len() > 30 && u.len() < 80, "len={}", u.len());
        assert!(u.windows(2).all(|w| w[0] < w[1]));
        assert!((u[0] - 1.0 / 14.0).abs() < 1e-6);
    }
}
