//! Voronoi codes (Conway & Sloane 1983) over the Gosset lattice.
//!
//! Codebook C = Λ ∩ q·V_Λ ≅ Λ/qΛ, |C| = q^8 → rate R = log2(q) bits/entry.
//! Encode (paper Alg. 1): x → coordinates of Q_Λ(x) mod q.
//! Decode (paper Alg. 2): c → Gc − q·Q_Λ(Gc/q), the minimum-energy coset
//! representative.
//!
//! Arithmetic runs in the doubled lattice 2·E8, whose generator matrix G
//! (the paper's Appendix-E matrix) is integer, so coordinates and coset
//! arithmetic are exact in i64. Real-valued lattice points are recovered by
//! halving.

use super::e8::{nearest_e8, D};

/// Generator matrix of 2·E8 as printed in Appendix E (row-major). Columns
/// are the generators: Λ = { G·c : c ∈ Z^8 }. |det G| = 2^8 · covol(E8) = 256.
pub const G2E8: [[i64; D]; D] = [
    [1, 0, 0, 0, 0, 0, 0, 0],
    [1, 0, 2, 0, 0, 0, 0, 0],
    [1, 0, 0, 0, 2, 0, 0, 0],
    [1, 0, 0, 0, 0, 0, 2, 0],
    [1, 4, 2, 2, 2, 2, 2, 2],
    [1, 0, 0, 2, 0, 0, 0, 0],
    [1, 0, 0, 0, 0, 2, 0, 0],
    [1, 0, 0, 0, 0, 0, 0, 2],
];

/// det(G2E8) and the adjugate, computed once (exactly) at codec build time.
fn det_and_adjugate(g: &[[i64; D]; D]) -> (i64, [[i64; D]; D]) {
    // Fraction-free determinant via i128 Bareiss elimination.
    let mut a: Vec<Vec<i128>> = g
        .iter()
        .map(|row| row.iter().map(|&x| x as i128).collect())
        .collect();
    let mut det_sign = 1i128;
    let mut prev = 1i128;
    for k in 0..D - 1 {
        if a[k][k] == 0 {
            let swap = (k + 1..D).find(|&i| a[i][k] != 0).expect("singular G");
            a.swap(k, swap);
            det_sign = -det_sign;
        }
        for i in k + 1..D {
            for j in k + 1..D {
                a[i][j] = (a[k][k] * a[i][j] - a[i][k] * a[k][j]) / prev;
            }
            a[i][k] = 0;
        }
        prev = a[k][k];
    }
    let det = (det_sign * a[D - 1][D - 1]) as i64;

    // Adjugate via cofactors (8×8, one-time cost).
    let minor_det = |g: &[[i64; D]; D], skip_r: usize, skip_c: usize| -> i128 {
        let mut m: Vec<Vec<i128>> = Vec::with_capacity(D - 1);
        for (r, row) in g.iter().enumerate() {
            if r == skip_r {
                continue;
            }
            m.push(
                row.iter()
                    .enumerate()
                    .filter(|&(c, _)| c != skip_c)
                    .map(|(_, &x)| x as i128)
                    .collect(),
            );
        }
        // Bareiss on the 7×7 minor.
        let n = D - 1;
        let mut sign = 1i128;
        let mut prev = 1i128;
        for k in 0..n - 1 {
            if m[k][k] == 0 {
                let Some(swap) = (k + 1..n).find(|&i| m[i][k] != 0) else {
                    return 0;
                };
                m.swap(k, swap);
                sign = -sign;
            }
            for i in k + 1..n {
                for j in k + 1..n {
                    m[i][j] = (m[k][k] * m[i][j] - m[i][k] * m[k][j]) / prev;
                }
                m[i][k] = 0;
            }
            prev = m[k][k];
        }
        sign * m[n - 1][n - 1]
    };

    let mut adj = [[0i64; D]; D];
    for r in 0..D {
        for c in 0..D {
            let cof = minor_det(g, r, c);
            let sign = if (r + c) % 2 == 0 { 1 } else { -1 };
            // adjugate = transpose of cofactor matrix
            adj[c][r] = (sign as i128 * cof) as i64;
        }
    }
    (det, adj)
}

/// A Voronoi codec for E8 at nesting ratio `q` (rate log2(q) bits/entry).
#[derive(Clone, Debug)]
pub struct VoronoiCodec {
    /// nesting ratio; codebook size q^8
    pub q: i64,
    /// use the simplified NestQuantM oracle on the decode side (App. D)
    pub m_variant: bool,
    det: i64,
    adj: [[i64; D]; D],
}

impl VoronoiCodec {
    pub fn new(q: u32) -> Self {
        Self::with_variant(q, false)
    }

    /// NestQuantM codec: full oracle for encoding, fixed-flip oracle for
    /// decoding (Appendix D).
    pub fn new_m(q: u32) -> Self {
        Self::with_variant(q, true)
    }

    fn with_variant(q: u32, m_variant: bool) -> Self {
        assert!(q >= 2 && q <= 255, "q must be in [2, 255], got {q}");
        let (det, adj) = det_and_adjugate(&G2E8);
        debug_assert_eq!(det.abs(), 256);
        VoronoiCodec {
            q: q as i64,
            m_variant,
            det,
            adj,
        }
    }

    /// Rate in bits per entry: log2(q).
    pub fn rate(&self) -> f64 {
        (self.q as f64).log2()
    }

    /// Nearest E8 point of `x` (the encoder-side oracle is always exact).
    #[inline]
    pub fn nearest(&self, x: &[f32; D]) -> [f32; D] {
        nearest_e8(x)
    }

    /// Paper Algorithm 1: quantize x to the coset code of its nearest
    /// lattice point. Returns codes in [0, q)^8.
    #[inline]
    pub fn encode(&self, x: &[f32; D]) -> [u8; D] {
        let p = nearest_e8(x);
        self.encode_point(&p)
    }

    /// Coset code of a lattice point p ∈ E8.
    #[inline]
    pub fn encode_point(&self, p: &[f32; D]) -> [u8; D] {
        // t = 2p is an integer vector in 2E8; coordinates v = G⁻¹ t = adj·t/det.
        let mut t = [0i64; D];
        for i in 0..D {
            t[i] = (2.0 * p[i]).round() as i64;
            debug_assert_eq!(t[i] as f32, 2.0 * p[i], "p not in ½Z^8");
        }
        let mut c = [0u8; D];
        for i in 0..D {
            let mut acc = 0i128;
            for j in 0..D {
                acc += self.adj[i][j] as i128 * t[j] as i128;
            }
            debug_assert_eq!(acc % self.det as i128, 0, "2p not in 2E8");
            let v = (acc / self.det as i128) as i64;
            c[i] = v.rem_euclid(self.q) as u8;
        }
        c
    }

    /// Paper Algorithm 2: reconstruct the minimum-energy representative of
    /// the coset (exactly Q_Λ(x) when the encoder was not in overload).
    ///
    /// Runs entirely in integer arithmetic (see `decode_halfunits`), so
    /// coset ties break deterministically and identically across the
    /// float and packed (`quant::qgemm`) paths.
    #[inline]
    pub fn decode(&self, c: &[u8; D]) -> [f32; D] {
        let e = self.decode_halfunits(c);
        let mut out = [0f32; D];
        for i in 0..D {
            out[i] = e[i] as f32 * 0.5;
        }
        out
    }

    /// Integer decode: returns the decoded point in *half units* (decoded
    /// value = e/2 — always exact, the paper's int-multiplier observation).
    ///
    /// t = G·c ≥ 0 is twice the coset point; with m = 2q the two E8 coset
    /// candidates reduce to residuals
    ///   e1_i = t_i − m·round(t_i/m)       (D8: integer grid)
    ///   e2_i = t_i − q − m·floor(t_i/m)   (D8+½: half-integer grid)
    /// with a parity flip on the cheapest coordinate (or coordinate 0 for
    /// the NestQuantM variant, Appendix D); the smaller-cost candidate is
    /// the min-energy representative.
    #[inline]
    pub fn decode_halfunits(&self, c: &[u8; D]) -> [i32; D] {
        let mut t = [0i32; D];
        for i in 0..D {
            let mut acc = 0i32;
            for j in 0..D {
                acc += G2E8[i][j] as i32 * c[j] as i32;
            }
            t[i] = acc;
        }
        decode_t_halfunits(&t, self.q as i32, self.m_variant)
    }

    /// Encode and report (reconstruction, overload?). Overload ⇔ the
    /// decoded point differs from the true nearest point (Q_Λ(x) ∉ qV_Λ).
    // (kept below `decode` so the doc order mirrors Alg. 1/2)
    #[inline]
    pub fn encode_decode(&self, x: &[f32; D]) -> ([f32; D], bool) {
        let p = nearest_e8(x);
        let c = self.encode_point(&p);
        let r = self.decode(&c);
        (r, r != p)
    }
}

/// Core integer decode shared by `VoronoiCodec::decode` and the packed
/// GEMV fast path (`quant::qgemm`). `t = G·c ≥ 0`, result in half units.
#[inline(always)]
pub fn decode_t_halfunits(t: &[i32; D], q: i32, m_variant: bool) -> [i32; D] {
    let m = 2 * q;
    let mut e1 = [0i32; D];
    let mut e2 = [0i32; D];
    let mut par1 = 0i32;
    let mut par2 = 0i32;
    for i in 0..D {
        debug_assert!(t[i] >= 0);
        // D8 candidate: round-half-up(t/m) (t ≥ 0 ⇒ plain division).
        let r1 = (t[i] + q) / m;
        e1[i] = t[i] - m * r1;
        par1 += r1;
        // D8+½ candidate: round-half-up((t−q)/m) = floor(t/m).
        let r2 = t[i] / m;
        e2[i] = t[i] - q - m * r2;
        par2 += r2;
    }
    // Parity fixes: move the flip coordinate to its second-nearest grid
    // point, toward the input's side (e ≥ 0 → +1 ⇒ e −= m).
    if par1 & 1 != 0 {
        let pos = if m_variant { 0 } else { argmax_abs(&e1) };
        let dir = if e1[pos] >= 0 { 1 } else { -1 };
        e1[pos] -= m * dir;
    }
    if par2 & 1 != 0 {
        let pos = if m_variant { 0 } else { argmax_abs(&e2) };
        let dir = if e2[pos] >= 0 { 1 } else { -1 };
        e2[pos] -= m * dir;
    }
    let cost1: i64 = e1.iter().map(|&v| (v as i64) * (v as i64)).sum();
    let cost2: i64 = e2.iter().map(|&v| (v as i64) * (v as i64)).sum();
    if cost1 <= cost2 {
        e1
    } else {
        e2
    }
}

/// First index of maximal |e_i| — matches the float oracle's strict-`>`
/// argmax over flip costs.
#[inline(always)]
fn argmax_abs(e: &[i32; D]) -> usize {
    let mut best = 0usize;
    let mut best_v = -1i32;
    for (i, &v) in e.iter().enumerate() {
        let a = v.abs();
        if a > best_v {
            best_v = a;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Rng};

    #[test]
    fn g_columns_are_in_2e8() {
        use super::super::e8::e8_contains;
        for j in 0..D {
            let mut col = [0f32; D];
            for i in 0..D {
                col[i] = G2E8[i][j] as f32 / 2.0; // halved → must be in E8
            }
            assert!(e8_contains(&col), "column {j} not in 2E8: {col:?}");
        }
    }

    #[test]
    fn determinant_is_256() {
        let (det, adj) = det_and_adjugate(&G2E8);
        assert_eq!(det.abs(), 256);
        // G · adj = det · I (adjugate identity), exactly in i64.
        for i in 0..D {
            for j in 0..D {
                let mut acc = 0i128;
                for k in 0..D {
                    acc += G2E8[i][k] as i128 * adj[k][j] as i128;
                }
                let expect = if i == j { det as i128 } else { 0 };
                assert_eq!(acc, expect, "G·adj mismatch at ({i},{j})");
            }
        }
    }

    #[test]
    fn roundtrip_without_overload_is_exact() {
        // For x well inside q·V_Λ, decode(encode(x)) == Q_Λ(x).
        propcheck::check("voronoi-roundtrip", 400, 201, |rng| {
            let codec = VoronoiCodec::new(16);
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32(); // σ=1 ≪ q/2 ⇒ overload ~never
            }
            let p = nearest_e8(&x);
            let c = codec.encode(&x);
            let r = codec.decode(&c);
            if r == p {
                Ok(())
            } else {
                Err(format!("decode {r:?} != nearest {p:?}"))
            }
        });
    }

    #[test]
    fn decode_is_in_lattice() {
        use super::super::e8::e8_contains;
        propcheck::check("voronoi-decode-lattice", 300, 202, |rng| {
            let codec = VoronoiCodec::new(5);
            let mut c = [0u8; D];
            for v in c.iter_mut() {
                *v = rng.below(5) as u8;
            }
            let r = codec.decode(&c);
            if e8_contains(&r) {
                Ok(())
            } else {
                Err(format!("decode({c:?}) = {r:?} not in E8"))
            }
        });
    }

    #[test]
    fn decode_encode_is_identity_on_codes() {
        // decode → encode_point must return the original coset code
        // (decode picks a coset representative; its coordinates mod q are
        // the code).
        propcheck::check("voronoi-code-roundtrip", 300, 203, |rng| {
            for &q in &[3u32, 4, 8, 14, 16] {
                let codec = VoronoiCodec::new(q);
                let mut c = [0u8; D];
                for v in c.iter_mut() {
                    *v = rng.below(q as usize) as u8;
                }
                let r = codec.decode(&c);
                let c2 = codec.encode_point(&r);
                if c2 != c {
                    return Err(format!("q={q}: code {c:?} → {r:?} → {c2:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn codebook_size_is_q_pow_8_for_small_q() {
        // q=2: enumerate all q^8 = 256 codes; all decode to distinct points.
        let codec = VoronoiCodec::new(2);
        let mut pts = std::collections::HashSet::new();
        for code_id in 0..256u32 {
            let mut c = [0u8; D];
            for (i, v) in c.iter_mut().enumerate() {
                *v = ((code_id >> i) & 1) as u8;
            }
            let r = codec.decode(&c);
            let key: Vec<i64> = r.iter().map(|&x| (2.0 * x) as i64).collect();
            pts.insert(key);
        }
        assert_eq!(pts.len(), 256);
    }

    #[test]
    fn decoded_points_are_min_energy_representatives() {
        // Each decoded point must have norm ≤ any shifted coset member
        // p + q·g for generator columns g (local minimality check).
        let codec = VoronoiCodec::new(4);
        let mut rng = Rng::new(204);
        for _ in 0..200 {
            let mut c = [0u8; D];
            for v in c.iter_mut() {
                *v = rng.below(4) as u8;
            }
            let r = codec.decode(&c);
            let n0: f32 = r.iter().map(|&x| x * x).sum();
            for j in 0..D {
                for sgn in [-1f32, 1.0] {
                    let mut shifted = r;
                    for i in 0..D {
                        shifted[i] += sgn * codec.q as f32 * G2E8[i][j] as f32 / 2.0;
                    }
                    let n1: f32 = shifted.iter().map(|&x| x * x).sum();
                    assert!(
                        n0 <= n1 + 1e-3,
                        "decode not min-energy: |r|²={n0} vs shifted |r'|²={n1}"
                    );
                }
            }
        }
    }

    #[test]
    fn overload_detection() {
        let codec = VoronoiCodec::new(4);
        // A huge vector is certainly outside q·V_Λ → overload.
        let x = [100f32; D];
        let (_, overload) = codec.encode_decode(&x);
        assert!(overload);
        // A tiny vector is inside → no overload.
        let x = [0.1f32; D];
        let (r, overload) = codec.encode_decode(&x);
        assert!(!overload);
        assert_eq!(r, nearest_e8(&x));
    }

    #[test]
    fn m_variant_roundtrip_consistency() {
        // NestQuantM: encode with exact oracle, decode with f. For
        // non-overload points (w.r.t. the f-shaping region) the roundtrip
        // must still be the identity (Appendix D argument).
        propcheck::check("voronoi-m-roundtrip", 300, 205, |rng| {
            let codec = VoronoiCodec::new_m(16);
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32();
            }
            let p = nearest_e8(&x);
            let c = codec.encode(&x);
            let r = codec.decode(&c);
            // σ=1, q=16: f's shaping region still contains these typical
            // points; identity must hold.
            if r == p {
                Ok(())
            } else {
                Err(format!("M-decode {r:?} != nearest {p:?}"))
            }
        });
    }
}
