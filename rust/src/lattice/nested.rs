//! The NestQuant vector quantizer (paper Algorithm 3) and quantized dot
//! products (Algorithm 4).
//!
//! A vector of length n = 8·b is L2-normalized (×√n/s, s = ‖A‖₂), split
//! into 8-blocks, and each block is quantized to the best member of a
//! *union of scaled Voronoi codebooks* ⋃_t β_t · (Λ ∩ qV_Λ). The per-block
//! side information is the chosen β index (2 bits for k=4, zstd- or
//! entropy-compressible); the per-vector side information is the scale s.
//!
//! Effective rate: log2(q) + H(β)/8 bits per entry (§3, §5.1).

use super::e8::D;
use super::voronoi::VoronoiCodec;

/// β selection strategy (Appendix F).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Try every β, keep the one with smallest reconstruction MSE.
    OptBeta,
    /// Use the smallest β that does not overload (falls back to the
    /// largest β if all overload). Used by the β-selection DP.
    FirstBeta,
}

/// A quantized vector: packed coset codes + per-block β indices + scale.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantizedVector {
    /// n coset code entries in [0, q)
    pub codes: Vec<u8>,
    /// b = n/8 β indices in [0, k)
    pub beta_idx: Vec<u8>,
    /// original L2 norm s = ‖A‖₂
    pub scale: f32,
    /// logical length n
    pub n: usize,
}

/// Stored payload size in bits of an n-entry coded vector at rate
/// log2(q), plus 2 bits/block for β (uncompressed; k ≤ 4 assumed for the
/// 2-bit packing) and the f32 scale. Single source of truth for coded
/// payload accounting — the paged KV pool's page byte costs
/// (`kvpool::block`) derive from this too.
pub fn payload_bits_for(n: usize, q: u32) -> usize {
    let code_bits = (n as f64 * (q as f64).log2()).ceil() as usize;
    code_bits + 2 * (n / D) + 32 // + f32 scale
}

impl QuantizedVector {
    /// Stored payload size in bits (see [`payload_bits_for`]).
    pub fn payload_bits(&self, q: u32) -> usize {
        debug_assert_eq!(self.beta_idx.len(), self.n / D);
        payload_bits_for(self.n, q)
    }
}

/// The multi-β nested-lattice quantizer of §4 (Algorithm 3).
#[derive(Clone, Debug)]
pub struct NestedLatticeQuantizer {
    pub codec: VoronoiCodec,
    /// scaling coefficients β_1 < … < β_k
    pub betas: Vec<f32>,
    pub strategy: Strategy,
}

impl NestedLatticeQuantizer {
    pub fn new(q: u32, betas: Vec<f32>) -> Self {
        Self::with_codec(VoronoiCodec::new(q), betas, Strategy::OptBeta)
    }

    /// NestQuantM variant (simplified decode oracle, Appendix D).
    pub fn new_m(q: u32, betas: Vec<f32>) -> Self {
        Self::with_codec(VoronoiCodec::new_m(q), betas, Strategy::OptBeta)
    }

    pub fn with_codec(codec: VoronoiCodec, mut betas: Vec<f32>, strategy: Strategy) -> Self {
        assert!(!betas.is_empty(), "need at least one β");
        assert!(betas.len() <= 255);
        assert!(betas.iter().all(|&b| b > 0.0), "β must be positive");
        betas.sort_by(|a, b| a.partial_cmp(b).unwrap());
        NestedLatticeQuantizer {
            codec,
            betas,
            strategy,
        }
    }

    pub fn q(&self) -> u32 {
        self.codec.q as u32
    }

    pub fn k(&self) -> usize {
        self.betas.len()
    }

    /// Quantize one 8-block (already normalized). Returns
    /// (codes, β index, reconstruction, overloaded-at-chosen-β).
    #[inline]
    pub fn quantize_block(&self, v: &[f32; D]) -> ([u8; D], u8, [f32; D], bool) {
        let mut best_err = f32::INFINITY;
        let mut best: Option<([u8; D], u8, [f32; D], bool)> = None;
        for (t, &beta) in self.betas.iter().enumerate() {
            let inv = 1.0 / beta;
            let mut xs = [0f32; D];
            for i in 0..D {
                xs[i] = v[i] * inv;
            }
            let p = self.codec.nearest(&xs);
            let c = self.codec.encode_point(&p);
            let r = self.codec.decode(&c);
            let overload = r != p;
            let mut err = 0f32;
            for i in 0..D {
                let d = r[i] * beta - v[i];
                err += d * d;
            }
            match self.strategy {
                Strategy::OptBeta => {
                    if err < best_err {
                        best_err = err;
                        let mut recon = [0f32; D];
                        for i in 0..D {
                            recon[i] = r[i] * beta;
                        }
                        best = Some((c, t as u8, recon, overload));
                    }
                }
                Strategy::FirstBeta => {
                    let mut recon = [0f32; D];
                    for i in 0..D {
                        recon[i] = r[i] * beta;
                    }
                    if !overload {
                        return (c, t as u8, recon, false);
                    }
                    // remember the largest β as fallback
                    best = Some((c, t as u8, recon, true));
                }
            }
        }
        best.expect("betas nonempty")
    }

    /// Decode one 8-block given codes and β index.
    #[inline]
    pub fn decode_block(&self, codes: &[u8; D], beta_idx: u8) -> [f32; D] {
        let beta = self.betas[beta_idx as usize];
        let mut r = self.codec.decode(codes);
        for v in r.iter_mut() {
            *v *= beta;
        }
        r
    }

    /// Paper Algorithm 3: quantize a full vector (length divisible by 8).
    pub fn quantize(&self, a: &[f32]) -> QuantizedVector {
        let mut out = QuantizedVector {
            codes: Vec::new(),
            beta_idx: Vec::new(),
            scale: 0.0,
            n: 0,
        };
        self.quantize_into(a, &mut out);
        out
    }

    /// [`Self::quantize`] into a caller-owned [`QuantizedVector`] whose
    /// buffers are cleared and refilled (capacity reused) — the paged-KV
    /// append path codes one vector per (layer, head) per token and must
    /// not pay a per-token allocation.
    pub fn quantize_into(&self, a: &[f32], out: &mut QuantizedVector) {
        assert_eq!(a.len() % D, 0, "vector length must be divisible by 8");
        let n = a.len();
        let s = crate::util::stats::norm2(a) as f32;
        out.n = n;
        out.scale = s;
        out.codes.clear();
        out.codes.resize(n, 0);
        out.beta_idx.clear();
        out.beta_idx.resize(n / D, 0);
        if s == 0.0 {
            return;
        }
        let norm = (n as f32).sqrt() / s;
        let mut block = [0f32; D];
        for (j, chunk) in a.chunks_exact(D).enumerate() {
            for i in 0..D {
                block[i] = chunk[i] * norm;
            }
            let (c, t, _, _) = self.quantize_block(&block);
            out.codes[j * D..(j + 1) * D].copy_from_slice(&c);
            out.beta_idx[j] = t;
        }
    }

    /// Dequantize a full vector back to f32.
    pub fn dequantize(&self, qv: &QuantizedVector) -> Vec<f32> {
        let mut out = vec![0f32; qv.n];
        self.dequantize_into(qv, &mut out);
        out
    }

    /// [`Self::dequantize`] into a caller-provided slice of length
    /// `qv.n` — the allocation-free counterpart used by the activation
    /// fake-quant path of the fused decode step.
    pub fn dequantize_into(&self, qv: &QuantizedVector, out: &mut [f32]) {
        assert_eq!(out.len(), qv.n);
        if qv.scale == 0.0 {
            out.fill(0.0);
            return;
        }
        let denorm = qv.scale / (qv.n as f32).sqrt();
        for j in 0..qv.n / D {
            let mut c = [0u8; D];
            c.copy_from_slice(&qv.codes[j * D..(j + 1) * D]);
            let r = self.decode_block(&c, qv.beta_idx[j]);
            for i in 0..D {
                out[j * D + i] = r[i] * denorm;
            }
        }
    }

    /// One-shot quantize→dequantize ("fake quant"); bit-exact with
    /// dequantize(quantize(a)).
    pub fn roundtrip(&self, a: &[f32]) -> Vec<f32> {
        self.dequantize(&self.quantize(a))
    }

    /// Paper Algorithm 4: inner product of two quantized vectors without
    /// full dequantization. β scales are applied per block-pair; the
    /// normalization s1·s2/n is applied once.
    pub fn dot(&self, a: &QuantizedVector, b: &QuantizedVector) -> f32 {
        assert_eq!(a.n, b.n);
        if a.scale == 0.0 || b.scale == 0.0 {
            return 0.0;
        }
        let mut acc = 0f64;
        let mut ca = [0u8; D];
        let mut cb = [0u8; D];
        for j in 0..a.n / D {
            ca.copy_from_slice(&a.codes[j * D..(j + 1) * D]);
            cb.copy_from_slice(&b.codes[j * D..(j + 1) * D]);
            let pa = self.codec.decode(&ca);
            let pb = self.codec.decode(&cb);
            let mut d = 0f32;
            for i in 0..D {
                d += pa[i] * pb[i];
            }
            acc += (d * self.betas[a.beta_idx[j] as usize] * self.betas[b.beta_idx[j] as usize])
                as f64;
        }
        (acc * a.scale as f64 * b.scale as f64 / a.n as f64) as f32
    }

    /// Histogram of β usage over a sample of vectors — used for the
    /// effective-rate computation (§5.1) and Tables 1/3 bits columns.
    pub fn beta_histogram(&self, vectors: &[Vec<f32>]) -> Vec<u64> {
        let mut counts = vec![0u64; self.k()];
        for v in vectors {
            let qv = self.quantize(v);
            for &t in &qv.beta_idx {
                counts[t as usize] += 1;
            }
        }
        counts
    }

    /// Effective rate in bits/entry: log2(q) + H(β)/8 (entropy coding of
    /// the β side info; §5.1).
    pub fn effective_rate(&self, beta_counts: &[u64]) -> f64 {
        self.codec.rate() + crate::util::stats::entropy_bits(beta_counts) / D as f64
    }

    /// Raw rate with 2-bit β packing (the "no zstd" column; requires k ≤ 4).
    pub fn raw_rate(&self) -> f64 {
        let beta_bits = (self.k() as f64).log2().ceil().max(1.0);
        self.codec.rate() + beta_bits / D as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    fn quantizer(q: u32) -> NestedLatticeQuantizer {
        // βs tuned for N(0,1) blocks at q=14-ish rates (paper App. G shape)
        NestedLatticeQuantizer::new(q, vec![0.25, 0.32, 0.45, 1.0])
    }

    #[test]
    fn quantize_into_matches_quantize_and_reuses_capacity() {
        let mut rng = Rng::new(77);
        let nq = quantizer(14);
        let mut qv = QuantizedVector {
            codes: Vec::new(),
            beta_idx: Vec::new(),
            scale: 0.0,
            n: 0,
        };
        let mut buf = vec![0f32; 64];
        for n in [64usize, 128, 64] {
            let x = rng.gauss_vec(n);
            let fresh = nq.quantize(&x);
            nq.quantize_into(&x, &mut qv);
            assert_eq!(qv, fresh);
            buf.resize(n, 0.0);
            nq.dequantize_into(&qv, &mut buf);
            assert_eq!(buf, nq.dequantize(&fresh));
        }
        let cap = qv.codes.capacity();
        nq.quantize_into(&rng.gauss_vec(64), &mut qv);
        assert_eq!(qv.codes.capacity(), cap, "shrinking input must not reallocate");
    }

    #[test]
    fn roundtrip_close_for_gaussian() {
        let mut rng = Rng::new(301);
        let nq = quantizer(14);
        let a = rng.gauss_vec(256);
        let r = nq.roundtrip(&a);
        let rmse = stats::rmse(&a, &r);
        // ~4 bits/entry on normalized Gaussian: expect distortion well
        // under 0.1 RMSE (D(4) = 2^-8 ≈ 0.0039 MSE → 0.06 RMSE).
        assert!(rmse < 0.1, "rmse={rmse}");
    }

    #[test]
    fn dot_matches_dequantized_dot() {
        propcheck::check("alg4-dot-consistency", 50, 302, |rng| {
            let nq = quantizer(12);
            let a = rng.gauss_vec(64);
            let b = rng.gauss_vec(64);
            let qa = nq.quantize(&a);
            let qb = nq.quantize(&b);
            let fast = nq.dot(&qa, &qb) as f64;
            let da = nq.dequantize(&qa);
            let db = nq.dequantize(&qb);
            let slow = stats::dot(&da, &db);
            if (fast - slow).abs() < 1e-3 * (1.0 + slow.abs()) {
                Ok(())
            } else {
                Err(format!("alg4 dot {fast} vs dequantized dot {slow}"))
            }
        });
    }

    #[test]
    fn dot_approximates_true_inner_product() {
        let mut rng = Rng::new(303);
        let nq = quantizer(14);
        let n = 512;
        let mut err = stats::Welford::new();
        for _ in 0..50 {
            let a = rng.gauss_vec(n);
            let b = rng.gauss_vec(n);
            let qa = nq.quantize(&a);
            let qb = nq.quantize(&b);
            let approx = nq.dot(&qa, &qb) as f64;
            let exact = stats::dot(&a, &b);
            err.push(approx - exact);
        }
        // E(X·Y − approx)² should be ≈ n·Γ-ish; loose sanity: std ≪ √n·1
        assert!(err.std() < 0.5 * (n as f64).sqrt(), "std={}", err.std());
    }

    #[test]
    fn scale_invariance_of_normalization() {
        // Quantizing c·a reconstructs ≈ c·reconstruction(a): normalization
        // divides by ‖A‖₂ so block shapes are identical.
        let mut rng = Rng::new(304);
        let nq = quantizer(10);
        let a = rng.gauss_vec(128);
        let scaled: Vec<f32> = a.iter().map(|&x| 3.7 * x).collect();
        let ra = nq.roundtrip(&a);
        let rs = nq.roundtrip(&scaled);
        for (x, y) in ra.iter().zip(&rs) {
            assert!((3.7 * x - y).abs() < 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn zero_vector_roundtrip() {
        let nq = quantizer(8);
        let a = vec![0f32; 64];
        let r = nq.roundtrip(&a);
        assert_eq!(r, a);
        let qa = nq.quantize(&a);
        let qb = nq.quantize(&a);
        assert_eq!(nq.dot(&qa, &qb), 0.0);
    }

    #[test]
    fn first_beta_matches_opt_beta_closely() {
        // Table 5: First-β is only slightly worse than Opt-β.
        let mut rng = Rng::new(305);
        let betas = vec![0.22, 0.28, 0.38, 0.6, 1.2];
        let opt = NestedLatticeQuantizer::with_codec(
            VoronoiCodec::new(16),
            betas.clone(),
            Strategy::OptBeta,
        );
        let first = NestedLatticeQuantizer::with_codec(
            VoronoiCodec::new(16),
            betas,
            Strategy::FirstBeta,
        );
        let mut mse_opt = 0.0;
        let mut mse_first = 0.0;
        for _ in 0..200 {
            let a = rng.gauss_vec(64);
            mse_opt += stats::mse(&a, &opt.roundtrip(&a));
            mse_first += stats::mse(&a, &first.roundtrip(&a));
        }
        assert!(mse_opt <= mse_first + 1e-9);
        assert!(
            mse_first < mse_opt * 1.35,
            "first-β {mse_first} ≫ opt-β {mse_opt}"
        );
    }

    #[test]
    fn larger_q_reduces_error() {
        let mut rng = Rng::new(306);
        let a = rng.gauss_vec(512);
        let mut last = f64::INFINITY;
        for q in [4u32, 8, 16] {
            let nq = quantizer(q);
            let m = stats::mse(&a, &nq.roundtrip(&a));
            assert!(m < last, "q={q}: mse {m} not < {last}");
            last = m;
        }
    }

    #[test]
    fn payload_accounting() {
        let nq = quantizer(16);
        let mut rng = Rng::new(307);
        let a = rng.gauss_vec(64);
        let qv = nq.quantize(&a);
        // 64 entries × 4 bits + 8 blocks × 2 bits + 32-bit scale
        assert_eq!(qv.payload_bits(16), 64 * 4 + 8 * 2 + 32);
        assert_eq!(qv.codes.len(), 64);
        assert_eq!(qv.beta_idx.len(), 8);
        // effective rate ≤ raw rate
        let counts = nq.beta_histogram(std::slice::from_ref(&a));
        assert!(nq.effective_rate(&counts) <= nq.raw_rate() + 1e-12);
    }

    #[test]
    fn m_variant_quantizes_sanely() {
        let mut rng = Rng::new(308);
        let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
        let a = rng.gauss_vec(256);
        let rmse = stats::rmse(&a, &nq.roundtrip(&a));
        assert!(rmse < 0.12, "NestQuantM rmse={rmse}");
    }
}
