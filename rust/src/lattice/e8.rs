//! Closest-point oracles for the Gosset lattice E8.
//!
//! E8 = D8 ∪ (D8 + ½·1), where D8 = { v ∈ Z^8 : Σv_i even }. The classic
//! Conway–Sloane procedure (paper Appendix C, Algorithm 5): round to each
//! coset's grid, fix parity by flipping the cheapest coordinate, keep the
//! closer candidate. All arithmetic is exact in f32 (values are multiples
//! of ½).
//!
//! The NestQuantM variant (Appendix D) replaces the argmin/argmax flip
//! position with a fixed position 0 — cheaper in hardware — and is used on
//! the *decode* side only. It satisfies f(x + v) = f(x) + v for v ∈ E8
//! (Lemma D.1), which keeps Voronoi decoding consistent; the effective
//! shaping region changes slightly.

/// Block dimension of the Gosset lattice.
pub const D: usize = 8;

/// Round half *up* (systematic tie-break). Chosen over `f32::round`
/// (half away from zero) so the float oracle and the integer fast-decode
/// path in `quant::qgemm` agree exactly, including on tie points.
#[inline]
fn round_sys(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// Nearest point in D8 (integer vectors with even coordinate sum).
///
/// `forced_flip`: when the parity of the rounded vector is odd, flip the
/// rounding of this coordinate instead of the cheapest one (NestQuantM).
#[inline]
pub fn nearest_d8(x: &[f32; D], forced_flip: Option<usize>) -> [f32; D] {
    let mut r = [0f32; D];
    let mut parity = 0i64;
    for i in 0..D {
        r[i] = round_sys(x[i]);
        parity += r[i] as i64;
    }
    if parity & 1 != 0 {
        // Flipping coordinate i to its second-nearest integer costs
        // (1-a)^2 - a^2 = 1 - 2a where a = |x_i - r_i|; minimize cost by
        // maximizing a (unless the flip position is forced).
        let pos = match forced_flip {
            Some(p) => p,
            None => {
                let mut best = 0usize;
                let mut best_a = -1f32;
                for i in 0..D {
                    let a = (x[i] - r[i]).abs();
                    if a > best_a {
                        best_a = a;
                        best = i;
                    }
                }
                best
            }
        };
        // Move toward x's side of the rounded value (tie -> +1).
        r[pos] += if x[pos] >= r[pos] { 1.0 } else { -1.0 };
    }
    r
}

#[inline]
fn dist_sq(x: &[f32; D], y: &[f32; D]) -> f32 {
    let mut s = 0f32;
    for i in 0..D {
        let d = x[i] - y[i];
        s += d * d;
    }
    s
}

#[inline]
fn nearest_e8_impl(x: &[f32; D], forced_flip: Option<usize>) -> [f32; D] {
    // Candidate in D8.
    let c1 = nearest_d8(x, forced_flip);
    // Candidate in D8 + 1/2: shift, round in D8, shift back.
    let mut xs = [0f32; D];
    for i in 0..D {
        xs[i] = x[i] - 0.5;
    }
    let mut c2 = nearest_d8(&xs, forced_flip);
    for v in c2.iter_mut() {
        *v += 0.5;
    }
    // Systematic tie-break: prefer the D8 candidate.
    if dist_sq(x, &c1) <= dist_sq(x, &c2) {
        c1
    } else {
        c2
    }
}

/// Exact nearest point in E8 (Conway–Sloane; paper Algorithm 5).
#[inline]
pub fn nearest_e8(x: &[f32; D]) -> [f32; D] {
    nearest_e8_impl(x, None)
}

/// NestQuantM oracle `f` (Appendix D): parity flips always use coordinate 0.
/// Not an exact closest-point map, but E8-shift-equivariant (Lemma D.1).
#[inline]
pub fn nearest_e8_m(x: &[f32; D]) -> [f32; D] {
    nearest_e8_impl(x, Some(0))
}

/// Is `v` a point of E8? (all-integer with even sum, or all-half-integer
/// with `v - ½·1` in D8).
pub fn e8_contains(v: &[f32; D]) -> bool {
    let all_int = v.iter().all(|&x| x.fract() == 0.0);
    if all_int {
        let s: i64 = v.iter().map(|&x| x as i64).sum();
        return s & 1 == 0;
    }
    let all_half = v.iter().all(|&x| (x - 0.5).fract() == 0.0);
    if all_half {
        let s: i64 = v.iter().map(|&x| (x - 0.5) as i64).sum();
        return s & 1 == 0;
    }
    false
}

/// Normalized second moment of E8, ≈ 0.0716821 (Agrell & Allen 2023).
/// Used as a reference value in tests and the bounds module.
pub const E8_NSM: f64 = 0.071_682_1;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{propcheck, Rng};

    fn rand_e8_point(rng: &mut Rng) -> [f32; D] {
        // Random small E8 point: D8 part + optional half shift.
        let mut v = [0f32; D];
        let mut sum = 0i64;
        for x in v.iter_mut().take(D - 1) {
            let z = rng.below(9) as i64 - 4;
            *x = z as f32;
            sum += z;
        }
        // fix parity with last coordinate
        let mut last = rng.below(9) as i64 - 4;
        if (sum + last) & 1 != 0 {
            last += 1;
        }
        v[D - 1] = last as f32;
        if rng.next_u64() & 1 == 0 {
            for x in v.iter_mut() {
                *x += 0.5;
            }
        }
        v
    }

    #[test]
    fn returns_lattice_points() {
        propcheck::check("e8-membership", 500, 101, |rng| {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32() * 3.0;
            }
            let p = nearest_e8(&x);
            if e8_contains(&p) {
                Ok(())
            } else {
                Err(format!("{p:?} not in E8 (input {x:?})"))
            }
        });
    }

    #[test]
    fn m_variant_returns_lattice_points() {
        propcheck::check("e8m-membership", 500, 102, |rng| {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32() * 3.0;
            }
            let p = nearest_e8_m(&x);
            if e8_contains(&p) {
                Ok(())
            } else {
                Err(format!("{p:?} not in E8 (input {x:?})"))
            }
        });
    }

    #[test]
    fn idempotent_on_lattice_points() {
        propcheck::check("e8-idempotent", 300, 103, |rng| {
            let v = rand_e8_point(rng);
            let p = nearest_e8(&v);
            if p == v {
                Ok(())
            } else {
                Err(format!("Q({v:?}) = {p:?}"))
            }
        });
    }

    #[test]
    fn shift_equivariance_exact_oracle() {
        propcheck::check("e8-equivariance", 300, 104, |rng| {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32();
            }
            let shift = rand_e8_point(rng);
            let mut xs = x;
            for i in 0..D {
                xs[i] += shift[i];
            }
            let a = nearest_e8(&xs);
            let mut b = nearest_e8(&x);
            for i in 0..D {
                b[i] += shift[i];
            }
            // Ties may break differently after a shift; accept equal distance.
            let da = dist_sq(&xs, &a);
            let db = dist_sq(&xs, &b);
            if (da - db).abs() < 1e-5 {
                Ok(())
            } else {
                Err(format!("|x+v - Q(x+v)|²={da} vs |x+v - (Q(x)+v)|²={db}"))
            }
        });
    }

    #[test]
    fn m_variant_shift_equivariance_lemma_d1() {
        // Lemma D.1: f(x+v) = f(x)+v exactly (no tie caveat: the flip
        // position is fixed, so the decision is translation covariant).
        propcheck::check("e8m-equivariance", 300, 105, |rng| {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                // keep away from tie boundaries
                *v = rng.gauss_f32() * 1.7 + 0.123;
            }
            let shift = rand_e8_point(rng);
            let mut xs = x;
            for i in 0..D {
                xs[i] += shift[i];
            }
            let a = nearest_e8_m(&xs);
            let mut b = nearest_e8_m(&x);
            for i in 0..D {
                b[i] += shift[i];
            }
            if a == b {
                Ok(())
            } else {
                Err(format!("f(x+v)={a:?} != f(x)+v={b:?}"))
            }
        });
    }

    #[test]
    fn beats_or_matches_brute_force_neighbors() {
        // The returned point must be at least as close as any point in a
        // local enumeration of E8 around x.
        propcheck::check("e8-local-optimality", 40, 106, |rng| {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32() * 1.5;
            }
            let p = nearest_e8(&x);
            let dp = dist_sq(&x, &p);
            // Enumerate all E8 points with coordinates in round(x_i) ± 1.5
            // (covering radius of E8 is 1, so the true nearest point lies
            // in this box).
            let mut best = f32::INFINITY;
            // integer grid
            let base: Vec<i64> = x.iter().map(|&v| v.round() as i64).collect();
            let mut cand = [0f32; D];
            for mask in 0..3usize.pow(8) {
                let mut m = mask;
                let mut sum = 0i64;
                for i in 0..D {
                    let off = (m % 3) as i64 - 1;
                    m /= 3;
                    let c = base[i] + off;
                    cand[i] = c as f32;
                    sum += c;
                }
                if sum & 1 == 0 {
                    best = best.min(dist_sq(&x, &cand));
                }
                // half-integer grid: shift the same enumeration by +0.5
                let mut m = mask;
                let mut sumh = 0i64;
                for i in 0..D {
                    let off = (m % 3) as i64 - 1;
                    m /= 3;
                    // nearest half-integer below x_i is floor(x_i-0.5)+0.5
                    let c = (x[i] - 0.5).round() as i64 + off;
                    cand[i] = c as f32 + 0.5;
                    sumh += c;
                }
                if sumh & 1 == 0 {
                    best = best.min(dist_sq(&x, &cand));
                }
            }
            if dp <= best + 1e-5 {
                Ok(())
            } else {
                Err(format!("oracle dist² {dp} > brute-force {best} at {x:?}"))
            }
        });
    }

    #[test]
    fn nsm_statistical_estimate() {
        // Quantize x ~ N(0, σ²I) with σ large (pure granular regime) and
        // check E||x-Q(x)||²/8 ≈ NSM (covol 1 → per-dim MSE = NSM).
        let mut rng = Rng::new(2024);
        let mut acc = 0f64;
        const N: usize = 60_000;
        for _ in 0..N {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32() * 8.0;
            }
            let p = nearest_e8(&x);
            acc += dist_sq(&x, &p) as f64;
        }
        let mse_per_dim = acc / (N * D) as f64;
        let rel = (mse_per_dim - E8_NSM).abs() / E8_NSM;
        assert!(
            rel < 0.03,
            "measured NSM {mse_per_dim} vs expected {E8_NSM} (rel err {rel})"
        );
    }

    #[test]
    fn gosset_beats_scalar_quantizer_nsm() {
        // The shaping/granular gain of §3: G(Z)=1/12 vs G(E8)≈0.0717.
        assert!(E8_NSM < 1.0 / 12.0);
        // paper: E8 achieves a 1.16x gain over Z per-dimension
        let gain = (1.0 / 12.0) / E8_NSM;
        assert!((gain - 1.16).abs() < 0.01, "gain={gain}");
    }
}
