//! M-level hierarchical nested-lattice codes (Kaplan & Ordentlich, ISIT
//! 2025) over the Gosset machinery, and the shared inner-product lookup
//! table that powers the LUT GEMM backend (`quant::lut`).
//!
//! ## Construction
//!
//! A block x ∈ R^8 is quantized to λ₀ = Q_Λ(x) and λ₀ is expanded in
//! "base q over the lattice": digit vectors d_ℓ ∈ Λ ∩ qV_Λ with
//!
//!   λ₀ = Σ_{ℓ=0}^{M−1} q^ℓ · d_ℓ        (d_ℓ = the coset decode of c_ℓ)
//!
//! computed by the integer residual recursion λ_{ℓ+1} = (λ_ℓ − d_ℓ)/q,
//! which is *exact*: c_ℓ is the coset code of λ_ℓ, so λ_ℓ − d_ℓ ∈ qΛ and
//! the division stays on the (half-)integer grid. Decode telescopes back
//! to λ₀ identically — the M-level codec reconstructs exactly the same
//! point as the flat codec at nesting ratio q^M whenever neither
//! overloads (`equal_rate_exactness` propcheck), at M·log2(q) bits/dim.
//!
//! Overload ⇔ the residual after M digits is nonzero (λ₀ ∉ q^M·V_Λ).
//!
//! ## Successive refinement
//!
//! Digit ℓ carries weight q^ℓ, so the *top* m digits (levels M−m..M) are
//! the most significant: dropping the fine levels leaves
//! Σ_{ℓ≥M−m} q^ℓ d_ℓ = q^{M−m}·λ_{M−m}, i.e. the same point quantized at
//! granularity q^{M−m}. Stronger: the top m digits are bit-for-bit the
//! m-level encoding of the coarse point λ_{M−m} (the recursion is
//! idempotent on lattice points) — the `truncation_is_m_level_encoding`
//! propcheck. This is the substrate for tiered / draft-then-verify KV.
//!
//! ## The pair LUT
//!
//! Each digit packs into one index i = Σ c_j q^j < q^8 (u16 for q ≤ 3).
//! One shared symmetric table T[i_a][i_b] = ⟨decode(i_a), decode(i_b)⟩
//! serves *every* level pair: the block inner product of two M-level
//! codes is Σ_{ℓ,m} q^{ℓ+m} T[i_ℓ^a][i_m^b] — M² lookups, no decode.
//! Entries are exact integers in half-units² (|coord| ≤ 2q half-units ⇒
//! |T| ≤ 8·(2q)² = 32q², comfortably i16), and the whole double sum fits
//! i32 for every supported (q, M) — see [`lut_supported`]. The only
//! inexactness of a LUT dot product is therefore the quantization error
//! itself plus f32 scale application: with ε_a = â − a, ε_w = ŵ − w,
//!
//!   |⟨â,ŵ⟩ − ⟨a,w⟩| ≤ ‖ε_a‖·‖w‖ + ‖ε_w‖·‖a‖ + ‖ε_a‖·‖ε_w‖
//!
//! the documented two-sided bound (EXPERIMENTS.md §LUT backend).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock, Weak};

use super::e8::{nearest_e8, D};
use super::voronoi::VoronoiCodec;

/// Largest nesting ratio the generic hierarchical codec accepts (matches
/// the packed/`DecodeConsts` range; the LUT path is further restricted to
/// q ≤ [`LUT_MAX_Q`] by table size).
pub const MAX_Q: u32 = 16;
/// Most levels the codec supports; beyond this the u16/i32 windows and
/// payload math stop being interesting (q=2, M=8 is already 8 bits/dim).
pub const MAX_LEVELS: usize = 8;
/// Largest q whose packed block index q^8 fits u16 (3^8 = 6561).
pub const LUT_MAX_Q: u32 = 3;

/// Decoded digit coordinates are bounded by `2·q` in half-units (this
/// constant is the 2): the coset residual lies in [−q, q) and the parity
/// flip moves one coordinate by m = 2q toward the origin side, landing in
/// (−2q, 2q). Pinned by the `decoded_digit_coords_bounded` test; the
/// i16/i32 safety windows below are derived from it.
pub const DIGIT_BOUND_PER_Q: i64 = 2;

/// An M-level hierarchical codec at base nesting ratio q: encodes one
/// 8-block into M digit vectors (finest digit first), rate M·log2(q)
/// bits/dim before β side info.
#[derive(Clone, Debug)]
pub struct HierarchicalCodec {
    codec: VoronoiCodec,
    m_levels: usize,
}

impl HierarchicalCodec {
    /// Build an M-level codec. The digit codec is the NestQuantM variant
    /// so digit decode agrees bit-for-bit with the `DecodeConsts` integer
    /// path used to build the LUT.
    pub fn new(q: u32, m_levels: usize) -> Self {
        assert!((2..=MAX_Q).contains(&q), "q must be in [2, {MAX_Q}], got {q}");
        assert!(
            (1..=MAX_LEVELS).contains(&m_levels),
            "m_levels must be in [1, {MAX_LEVELS}], got {m_levels}"
        );
        HierarchicalCodec {
            codec: VoronoiCodec::new_m(q),
            m_levels,
        }
    }

    pub fn q(&self) -> u32 {
        self.codec.q as u32
    }

    pub fn m_levels(&self) -> usize {
        self.m_levels
    }

    /// Rate in bits per entry: M·log2(q).
    pub fn rate(&self) -> f64 {
        self.m_levels as f64 * (self.codec.q as f64).log2()
    }

    /// Bytes of digit storage per 8-block (one byte per digit coordinate,
    /// the unpacked `QuantizedMatrix` convention).
    pub fn digits_per_block(&self) -> usize {
        self.m_levels * D
    }

    /// Encode one block: `digits` receives M groups of 8 coset codes,
    /// level ℓ (weight q^ℓ) at `digits[ℓ*8..][..8]`, finest first.
    /// Returns the overload flag (true ⇔ Q_Λ(x) ∉ q^M·V_Λ, in which case
    /// decode reconstructs a different — wrapped — lattice point).
    pub fn encode_block(&self, x: &[f32; D], digits: &mut [u8]) -> bool {
        debug_assert_eq!(digits.len(), self.digits_per_block());
        // Track the residual lattice point in half-units (exact i32).
        let p = nearest_e8(x);
        let mut h = [0i32; D];
        for i in 0..D {
            h[i] = (2.0 * p[i]).round() as i32;
            debug_assert_eq!(h[i] as f32, 2.0 * p[i], "nearest_e8 not on ½Z^8");
        }
        let q = self.codec.q as i32;
        let mut pt = [0f32; D];
        for l in 0..self.m_levels {
            for i in 0..D {
                pt[i] = h[i] as f32 * 0.5;
            }
            let c = self.codec.encode_point(&pt);
            let d = self.codec.decode_halfunits(&c);
            digits[l * D..(l + 1) * D].copy_from_slice(&c);
            for i in 0..D {
                // λ_ℓ − d_ℓ ∈ qΛ: the division is exact on the integer grid.
                let r = h[i] - d[i];
                debug_assert_eq!(r % q, 0, "digit residual not divisible by q");
                h[i] = r / q;
            }
        }
        h != [0i32; D]
    }

    /// Exact decode of the full M-level code, in half-units:
    /// out = 2·Σ q^ℓ d_ℓ computed by Horner from the most significant
    /// digit. Equals 2·Q_Λ(x) when the encoder did not overload.
    pub fn decode_halfunits(&self, digits: &[u8], out: &mut [i32; D]) {
        self.coarse_halfunits(digits, self.m_levels, out);
    }

    /// Decode only the top `m` levels at their own scale: returns
    /// h = 2·λ_{M−m} (half-units of the *coarse* lattice point; multiply
    /// by q^{M−m} for the original scale). `m == m_levels` is the full
    /// decode.
    pub fn coarse_halfunits(&self, digits: &[u8], m: usize, out: &mut [i32; D]) {
        debug_assert_eq!(digits.len(), self.digits_per_block());
        assert!(m >= 1 && m <= self.m_levels, "truncation level out of range");
        let q = self.codec.q as i32;
        let mut c = [0u8; D];
        out.fill(0);
        for l in (self.m_levels - m..self.m_levels).rev() {
            c.copy_from_slice(&digits[l * D..(l + 1) * D]);
            let d = self.codec.decode_halfunits(&c);
            for i in 0..D {
                out[i] = out[i] * q + d[i];
            }
        }
    }

    /// Full f32 decode (the reconstructed lattice point).
    pub fn decode_block(&self, digits: &[u8]) -> [f32; D] {
        let mut h = [0i32; D];
        self.decode_halfunits(digits, &mut h);
        let mut out = [0f32; D];
        for i in 0..D {
            out[i] = h[i] as f32 * 0.5;
        }
        out
    }

    /// The successive-refinement view: reconstruction from only the top
    /// `m` levels, in the original scale — the fine digits are dropped,
    /// leaving x quantized at granularity q^{M−m}.
    pub fn decode_truncated(&self, digits: &[u8], m: usize) -> [f32; D] {
        let mut h = [0i32; D];
        self.coarse_halfunits(digits, m, &mut h);
        let scale = (self.codec.q as f32).powi((self.m_levels - m) as i32) * 0.5;
        let mut out = [0f32; D];
        for i in 0..D {
            out[i] = h[i] as f32 * scale;
        }
        out
    }
}

/// Pack one digit group (8 coset codes < q) into a flat codebook index
/// i = Σ c_j q^j < q^8. Only q ≤ [`LUT_MAX_Q`] fits u16.
#[inline]
pub fn pack_index(c: &[u8; D], q: u32) -> u16 {
    debug_assert!(q >= 2 && q <= LUT_MAX_Q);
    let mut idx = 0u32;
    for j in (0..D).rev() {
        debug_assert!((c[j] as u32) < q);
        idx = idx * q + c[j] as u32;
    }
    idx as u16
}

/// Inverse of [`pack_index`].
#[inline]
pub fn unpack_index(idx: u16, q: u32) -> [u8; D] {
    let mut c = [0u8; D];
    let mut v = idx as u32;
    for cj in c.iter_mut() {
        *cj = (v % q) as u8;
        v /= q;
    }
    debug_assert_eq!(v, 0);
    c
}

/// Number of packed indices at base q: q^8.
#[inline]
pub fn codebook_size(q: u32) -> usize {
    (q as usize).pow(D as u32)
}

/// Whether the LUT inner-product path serves a (q, m_levels) pair:
/// q ∈ {2, 3} (table is q^16 entries — q=2: 128 KiB, q=3: ~82 MiB;
/// beyond that it stops being a *small* lookup table and the block index
/// no longer fits u16), m_levels ∈ [2, 8], and the worst-case M²-term
/// accumulation must fit i32:
///
///   |Σ_{ℓ,m} q^{ℓ+m} T| ≤ ((q^M−1)/(q−1))² · 32q² < 2³¹
///
/// which admits every M ≤ 8 at q=2 and M ≤ 7 at q=3.
pub fn lut_supported(q: u32, m_levels: u32) -> bool {
    if !(2..=LUT_MAX_Q).contains(&q) || !(2..=MAX_LEVELS as u32).contains(&m_levels) {
        return false;
    }
    let q = q as i64;
    let radix = (q.pow(m_levels) - 1) / (q - 1); // Σ_{ℓ<M} q^ℓ
    let entry_bound = D as i64 * (DIGIT_BOUND_PER_Q * q).pow(2); // 8·(2q)²
    radix * radix * entry_bound <= i32::MAX as i64
}

/// The shared symmetric inner-product table at base q:
/// `table[ia*n + ib] = ⟨decode(ia), decode(ib)⟩` in half-units² (i.e.
/// 4× the real product — callers fold the ¼ into the β scales). One
/// table serves all level pairs of all matrices at this q, so it is
/// built once per process and shared via [`PairLut::shared`].
pub struct PairLut {
    pub q: u32,
    /// codebook size q^8
    pub n: usize,
    /// n² exact products, row-major, symmetric, plus one trailing zero
    /// pad: the AVX2 LUT kernel gathers 32 bits per 16-bit entry, so a
    /// lookup of the last real entry reads 2 bytes beyond it — the pad
    /// keeps that read inside the allocation.
    pub table: Vec<i16>,
}

impl PairLut {
    /// Build the table from scratch (q^16 decode products; prefer
    /// [`PairLut::shared`] which caches per q).
    pub fn build(q: u32) -> Self {
        assert!(
            (2..=LUT_MAX_Q).contains(&q),
            "pair LUT requires q in [2, {LUT_MAX_Q}], got {q}"
        );
        let n = codebook_size(q);
        // Decode every codebook entry once through the same integer path
        // the packed GEMV uses (DecodeConsts ≡ VoronoiCodec::new_m decode).
        let consts = crate::quant::qgemm::DecodeConsts::new(q as i32);
        let mut dec = vec![[0i16; D]; n];
        let mut e = [0i32; D];
        for (idx, d) in dec.iter_mut().enumerate() {
            let c = unpack_index(idx as u16, q);
            consts.decode(&c, &mut e);
            for i in 0..D {
                debug_assert!(e[i].abs() as i64 <= DIGIT_BOUND_PER_Q * q as i64);
                d[i] = e[i] as i16;
            }
        }
        let mut table = vec![0i16; n * n];
        for a in 0..n {
            let da = dec[a];
            // symmetric: fill the upper triangle and mirror
            for b in a..n {
                let db = dec[b];
                let mut acc = 0i32;
                for i in 0..D {
                    acc += da[i] as i32 * db[i] as i32;
                }
                debug_assert!(acc.unsigned_abs() <= 32 * q * q);
                table[a * n + b] = acc as i16;
                table[b * n + a] = acc as i16;
            }
        }
        // 16-bit-gather overhang pad (see the `table` field docs)
        table.push(0);
        PairLut { q, n, table }
    }

    /// Process-wide cache: the q=3 table is ~82 MiB, so it is shared by
    /// every matrix/engine at the same q and freed when the last user
    /// drops (Weak entries keep the map from pinning memory).
    pub fn shared(q: u32) -> Arc<PairLut> {
        static CACHE: OnceLock<Mutex<HashMap<u32, Weak<PairLut>>>> = OnceLock::new();
        let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = match cache.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Some(lut) = map.get(&q).and_then(Weak::upgrade) {
            return lut;
        }
        let lut = Arc::new(PairLut::build(q));
        map.insert(q, Arc::downgrade(&lut));
        lut
    }

    /// ⟨decode(ia), decode(ib)⟩ in half-units².
    #[inline(always)]
    pub fn inner(&self, ia: u16, ib: u16) -> i32 {
        self.table[ia as usize * self.n + ib as usize] as i32
    }

    /// Exact block inner product of two M-level codes via M² lookups:
    /// Σ_{ℓ,m} q^{ℓ+m}·T[ia_ℓ][ib_m], in half-units². Fits i32 for every
    /// [`lut_supported`] pair.
    #[inline]
    pub fn block_dot(&self, ia: &[u16], ib: &[u16]) -> i32 {
        debug_assert_eq!(ia.len(), ib.len());
        let q = self.q as i32;
        let mut acc = 0i32;
        let mut wl = 1i32; // q^ℓ
        for &a in ia {
            let row = &self.table[a as usize * self.n..(a as usize + 1) * self.n];
            let mut inner = 0i32;
            let mut wm = 1i32; // q^m
            for &b in ib {
                inner += wm * row[b as usize] as i32;
                wm *= q;
            }
            acc += wl * inner;
            wl *= q;
        }
        acc
    }
}

/// Multi-β hierarchical quantizer: Algorithm-3 shaping (per-row √n/‖·‖
/// normalization, per-block Opt-β over a β dictionary) with the M-level
/// codec as the block quantizer. Produces `QuantizedMatrix` storage with
/// `levels = M` (codes laid out `[row][block][level][coord]`).
#[derive(Clone, Debug)]
pub struct HierarchicalQuantizer {
    pub codec: HierarchicalCodec,
    /// scaling coefficients β_1 < … < β_k (k ≤ 4 for 2-bit packing)
    pub betas: Vec<f32>,
}

impl HierarchicalQuantizer {
    pub fn new(q: u32, m_levels: usize, mut betas: Vec<f32>) -> Self {
        assert!(!betas.is_empty(), "need at least one β");
        assert!(betas.len() <= 4, "hierarchical β dictionary is 2-bit packed (k ≤ 4)");
        assert!(betas.iter().all(|&b| b > 0.0), "β must be positive");
        betas.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        HierarchicalQuantizer {
            codec: HierarchicalCodec::new(q, m_levels),
            betas,
        }
    }

    pub fn q(&self) -> u32 {
        self.codec.q()
    }

    pub fn m_levels(&self) -> usize {
        self.codec.m_levels()
    }

    pub fn k(&self) -> usize {
        self.betas.len()
    }

    /// Quantize one normalized 8-block: Opt-β over the dictionary, digits
    /// of the winner written to `digits` (`m_levels·8` bytes). Returns
    /// (β index, reconstruction, overloaded-at-chosen-β).
    pub fn quantize_block(&self, v: &[f32; D], digits: &mut [u8]) -> (u8, [f32; D], bool) {
        debug_assert_eq!(digits.len(), self.codec.digits_per_block());
        let mut best_err = f32::INFINITY;
        let mut best_t = 0u8;
        let mut best_recon = [0f32; D];
        let mut best_over = false;
        let mut cand = [0u8; MAX_LEVELS * D];
        let nd = self.codec.digits_per_block();
        for (t, &beta) in self.betas.iter().enumerate() {
            let inv = 1.0 / beta;
            let mut xs = [0f32; D];
            for i in 0..D {
                xs[i] = v[i] * inv;
            }
            let overload = self.codec.encode_block(&xs, &mut cand[..nd]);
            let r = self.codec.decode_block(&cand[..nd]);
            let mut err = 0f32;
            let mut recon = [0f32; D];
            for i in 0..D {
                recon[i] = r[i] * beta;
                let d = recon[i] - v[i];
                err += d * d;
            }
            if err < best_err {
                best_err = err;
                best_t = t as u8;
                best_recon = recon;
                best_over = overload;
                digits.copy_from_slice(&cand[..nd]);
            }
        }
        (best_t, best_recon, best_over)
    }

    /// Quantize a full row (length divisible by 8) into caller buffers:
    /// `digits` gets cols·M code bytes (`[block][level][coord]`),
    /// `beta_idx` cols/8 entries. Returns the row scale s = ‖a‖₂.
    pub fn quantize_row(&self, a: &[f32], digits: &mut [u8], beta_idx: &mut [u8]) -> f32 {
        assert_eq!(a.len() % D, 0, "row length must be divisible by 8");
        let nd = self.codec.digits_per_block();
        debug_assert_eq!(digits.len(), (a.len() / D) * nd);
        debug_assert_eq!(beta_idx.len(), a.len() / D);
        let s = crate::util::stats::norm2(a) as f32;
        if s == 0.0 {
            digits.fill(0);
            beta_idx.fill(0);
            return 0.0;
        }
        let norm = (a.len() as f32).sqrt() / s;
        let mut block = [0f32; D];
        for (j, chunk) in a.chunks_exact(D).enumerate() {
            for i in 0..D {
                block[i] = chunk[i] * norm;
            }
            let (t, _, _) = self.quantize_block(&block, &mut digits[j * nd..(j + 1) * nd]);
            beta_idx[j] = t;
        }
        s
    }

    /// Quantize a dense matrix row-wise into `QuantizedMatrix` storage
    /// with `levels = M` — the carrier the engine's payload accounting
    /// and the packed LUT format both consume.
    pub fn quantize_matrix(&self, m: &crate::util::linalg::Mat) -> crate::quant::QuantizedMatrix {
        assert_eq!(m.cols % D, 0, "cols must be divisible by 8");
        let lv = self.m_levels();
        let mut codes = vec![0u8; m.rows * m.cols * lv];
        let mut beta_idx = vec![0u8; m.rows * m.cols / D];
        let mut scales = vec![0f32; m.rows];
        let per_row = m.cols * lv;
        let bpr = m.cols / D;
        for r in 0..m.rows {
            scales[r] = self.quantize_row(
                m.row(r),
                &mut codes[r * per_row..(r + 1) * per_row],
                &mut beta_idx[r * bpr..(r + 1) * bpr],
            );
        }
        crate::quant::QuantizedMatrix {
            rows: m.rows,
            cols: m.cols,
            q: self.q(),
            levels: lv as u32,
            codes,
            beta_idx,
            scales,
        }
    }

    /// Dequantize one row of an M-level `QuantizedMatrix` into `out`.
    pub fn dequantize_row(&self, digits: &[u8], beta_idx: &[u8], scale: f32, out: &mut [f32]) {
        let nd = self.codec.digits_per_block();
        debug_assert_eq!(digits.len(), beta_idx.len() * nd);
        debug_assert_eq!(out.len(), beta_idx.len() * D);
        if scale == 0.0 {
            out.fill(0.0);
            return;
        }
        let denorm = scale / (out.len() as f32).sqrt();
        for (j, &t) in beta_idx.iter().enumerate() {
            let r = self.codec.decode_block(&digits[j * nd..(j + 1) * nd]);
            let beta = self.betas[t as usize];
            for i in 0..D {
                out[j * D + i] = r[i] * beta * denorm;
            }
        }
    }

    /// Full dequantization of an M-level matrix (reference path for
    /// tests/propchecks; the LUT backend never calls this at serve time).
    pub fn dequantize_matrix(&self, qm: &crate::quant::QuantizedMatrix) -> crate::util::linalg::Mat {
        assert_eq!(qm.levels as usize, self.m_levels());
        let mut out = crate::util::linalg::Mat::zeros(qm.rows, qm.cols);
        let per_row = qm.cols * qm.levels as usize;
        let bpr = qm.cols / D;
        for r in 0..qm.rows {
            self.dequantize_row(
                &qm.codes[r * per_row..(r + 1) * per_row],
                &qm.beta_idx[r * bpr..(r + 1) * bpr],
                qm.scales[r],
                out.row_mut(r),
            );
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::{propcheck, stats, Rng};

    #[test]
    fn decoded_digit_coords_bounded() {
        // The |coord| ≤ 2q (i.e. 4q half-units / 2) bound every i16/i32
        // safety window rests on, verified exhaustively for the LUT qs.
        for q in 2..=LUT_MAX_Q {
            let codec = VoronoiCodec::new_m(q);
            for idx in 0..codebook_size(q) {
                let c = unpack_index(idx as u16, q);
                let e = codec.decode_halfunits(&c);
                for &v in &e {
                    assert!(
                        (v.abs() as i64) <= DIGIT_BOUND_PER_Q * q as i64,
                        "q={q} idx={idx}: |{v}| > 2q"
                    );
                }
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for q in 2..=LUT_MAX_Q {
            for idx in 0..codebook_size(q) as u16 {
                let c = unpack_index(idx, q);
                assert!(c.iter().all(|&v| (v as u32) < q));
                assert_eq!(pack_index(&c, q), idx, "q={q}");
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_is_exact_without_overload() {
        // Hierarchical decode must reproduce Q_Λ(x) exactly — for any
        // decode oracle, since the recursion consumes its own decodes.
        propcheck::check("hier-roundtrip", 300, 4101, |rng| {
            for &(q, m) in &[(2u32, 4usize), (2, 8), (3, 3), (3, 6), (4, 3), (16, 2)] {
                let codec = HierarchicalCodec::new(q, m);
                let mut x = [0f32; D];
                for v in x.iter_mut() {
                    *v = rng.gauss_f32();
                }
                let mut digits = vec![0u8; codec.digits_per_block()];
                let overload = codec.encode_block(&x, &mut digits);
                if overload {
                    continue; // σ=1 ≪ q^M/2: essentially never
                }
                let r = codec.decode_block(&digits);
                let p = nearest_e8(&x);
                if r != p {
                    return Err(format!("q={q} M={m}: decode {r:?} != Q_Λ(x) {p:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn equal_rate_exactness_vs_flat_codec() {
        // M levels at base q ≡ the flat codec at q^M: both reconstruct
        // exactly Q_Λ(x) on non-overloading inputs, so at equal rate the
        // codes describe the same point. (Flat codec caps at q ≤ 255.)
        propcheck::check("hier-equal-rate", 200, 4102, |rng| {
            for &(q, m) in &[(2u32, 4usize), (2, 7), (3, 4), (3, 5)] {
                let hier = HierarchicalCodec::new(q, m);
                let flat = VoronoiCodec::new_m(q.pow(m as u32));
                let mut x = [0f32; D];
                for v in x.iter_mut() {
                    *v = rng.gauss_f32();
                }
                let mut digits = vec![0u8; hier.digits_per_block()];
                let over_h = hier.encode_block(&x, &mut digits);
                let (rf, over_f) = flat.encode_decode(&x);
                if over_h || over_f {
                    continue;
                }
                let rh = hier.decode_block(&digits);
                if rh != rf {
                    return Err(format!(
                        "q={q} M={m}: hierarchical {rh:?} != flat q^M {rf:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn truncation_is_m_level_encoding() {
        // Successive refinement, exact form: the top m digits of an
        // M-level code ARE the m-level encoding of the coarse residual
        // point λ_{M−m} (encoding is idempotent on lattice points).
        propcheck::check("hier-truncate", 200, 4103, |rng| {
            for &(q, mm) in &[(2u32, 6usize), (3, 4), (4, 3)] {
                let codec = HierarchicalCodec::new(q, mm);
                let mut x = [0f32; D];
                for v in x.iter_mut() {
                    *v = rng.gauss_f32() * 2.0;
                }
                let mut digits = vec![0u8; codec.digits_per_block()];
                codec.encode_block(&x, &mut digits);
                for m in 1..=mm {
                    // coarse point λ_{M−m} from the top m digits
                    let mut h = [0i32; D];
                    codec.coarse_halfunits(&digits, m, &mut h);
                    let mut coarse_pt = [0f32; D];
                    for i in 0..D {
                        coarse_pt[i] = h[i] as f32 * 0.5;
                    }
                    // re-encoding it with an m-level codec must reproduce
                    // the top digit groups bit-for-bit
                    let sub = HierarchicalCodec::new(q, m);
                    let mut sub_digits = vec![0u8; sub.digits_per_block()];
                    let over = sub.encode_block(&coarse_pt, &mut sub_digits);
                    if over {
                        return Err(format!("q={q} M={mm} m={m}: coarse point overloads"));
                    }
                    let top = &digits[(mm - m) * D..];
                    if sub_digits != top {
                        return Err(format!(
                            "q={q} M={mm} m={m}: truncated digits differ from m-level code"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn truncation_error_decreases_with_levels() {
        // Statistical face of successive refinement: more retained levels
        // → smaller reconstruction error on gaussian blocks.
        let mut rng = Rng::new(4104);
        let codec = HierarchicalCodec::new(2, 6);
        let n_blocks = 200;
        let mut mse = vec![0f64; codec.m_levels()];
        let mut digits = vec![0u8; codec.digits_per_block()];
        for _ in 0..n_blocks {
            let mut x = [0f32; D];
            for v in x.iter_mut() {
                *v = rng.gauss_f32();
            }
            codec.encode_block(&x, &mut digits);
            for m in 1..=codec.m_levels() {
                let r = codec.decode_truncated(&digits, m);
                for i in 0..D {
                    mse[m - 1] += ((r[i] - x[i]) as f64).powi(2);
                }
            }
        }
        for m in 1..codec.m_levels() {
            assert!(
                mse[m] < mse[m - 1],
                "m={} mse {} not < m={} mse {}",
                m + 1,
                mse[m],
                m,
                mse[m - 1]
            );
        }
        // and the full decode is essentially exact vs Q_Λ(x): the last
        // tier's error is the lattice quantization error only
        assert!(mse[codec.m_levels() - 1] / (n_blocks * D) as f64 < 0.2);
    }

    #[test]
    fn overload_detection() {
        let codec = HierarchicalCodec::new(2, 3); // covers q^M = 8 · V_Λ
        let mut digits = vec![0u8; codec.digits_per_block()];
        assert!(codec.encode_block(&[100.0; D], &mut digits), "huge input must overload");
        assert!(!codec.encode_block(&[0.1; D], &mut digits), "tiny input must not");
    }

    #[test]
    fn lut_supported_window() {
        // derived from the documented i32 accumulation bound
        for m in 2..=8 {
            assert!(lut_supported(2, m), "q=2 M={m}");
        }
        for m in 2..=7 {
            assert!(lut_supported(3, m), "q=3 M={m}");
        }
        assert!(!lut_supported(3, 8), "q=3 M=8 overflows i32");
        assert!(!lut_supported(4, 2), "q=4 index exceeds u16");
        assert!(!lut_supported(2, 1), "single level is the flat codec");
        assert!(!lut_supported(2, 9));
        assert!(!lut_supported(1, 2));
    }

    #[test]
    fn pair_lut_entries_match_decoded_products() {
        let lut = PairLut::shared(2);
        assert_eq!(lut.n, 256);
        let codec = VoronoiCodec::new_m(2);
        let mut rng = Rng::new(4105);
        for _ in 0..500 {
            let ia = rng.below(lut.n) as u16;
            let ib = rng.below(lut.n) as u16;
            let ea = codec.decode_halfunits(&unpack_index(ia, 2));
            let eb = codec.decode_halfunits(&unpack_index(ib, 2));
            let expect: i32 = (0..D).map(|i| ea[i] * eb[i]).sum();
            assert_eq!(lut.inner(ia, ib), expect);
            assert_eq!(lut.inner(ib, ia), expect, "table must be symmetric");
        }
    }

    #[test]
    fn pair_lut_shared_is_cached() {
        let a = PairLut::shared(2);
        let b = PairLut::shared(2);
        assert!(Arc::ptr_eq(&a, &b), "same-q LUTs must share storage");
    }

    #[test]
    fn block_dot_is_exact_integer_inner_product() {
        // LUT M²-lookup block dot == integer dot of the decoded M-level
        // points — exactly, no tolerance.
        propcheck::check("hier-lut-block-dot", 200, 4106, |rng| {
            for &(q, m) in &[(2u32, 4usize), (2, 8), (3, 3)] {
                let codec = HierarchicalCodec::new(q, m);
                let lut = PairLut::shared(q);
                let mut xa = [0f32; D];
                let mut xb = [0f32; D];
                for i in 0..D {
                    xa[i] = rng.gauss_f32();
                    xb[i] = rng.gauss_f32();
                }
                let nd = codec.digits_per_block();
                let mut da = vec![0u8; nd];
                let mut db = vec![0u8; nd];
                codec.encode_block(&xa, &mut da);
                codec.encode_block(&xb, &mut db);
                let mut ia = vec![0u16; m];
                let mut ib = vec![0u16; m];
                for l in 0..m {
                    let mut c = [0u8; D];
                    c.copy_from_slice(&da[l * D..(l + 1) * D]);
                    ia[l] = pack_index(&c, q);
                    c.copy_from_slice(&db[l * D..(l + 1) * D]);
                    ib[l] = pack_index(&c, q);
                }
                let fast = lut.block_dot(&ia, &ib) as i64;
                let mut ha = [0i32; D];
                let mut hb = [0i32; D];
                codec.decode_halfunits(&da, &mut ha);
                codec.decode_halfunits(&db, &mut hb);
                let slow: i64 = (0..D).map(|i| ha[i] as i64 * hb[i] as i64).sum();
                if fast != slow {
                    return Err(format!("q={q} M={m}: lut {fast} != int {slow}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantizer_roundtrip_error_shrinks_with_levels() {
        let mut rng = Rng::new(4107);
        let a = rng.gauss_vec(256);
        let mut last = f64::INFINITY;
        for m in [2usize, 3, 4] {
            let hq = HierarchicalQuantizer::new(2, m, vec![0.6, 1.0, 1.6, 2.4]);
            let qm = hq.quantize_matrix(&crate::util::linalg::Mat::from_vec(1, 256, a.clone()));
            let deq = hq.dequantize_matrix(&qm);
            let e = stats::mse(&a, &deq.data);
            assert!(e < last, "M={m}: mse {e} not < {last}");
            last = e;
        }
    }

    #[test]
    fn quantize_matrix_levels_and_payload() {
        let mut rng = Rng::new(4108);
        let w = crate::util::linalg::Mat::from_vec(4, 64, rng.gauss_vec(256));
        let hq = HierarchicalQuantizer::new(2, 3, vec![0.8, 1.4]);
        let qm = hq.quantize_matrix(&w);
        assert_eq!(qm.levels, 3);
        assert_eq!(qm.codes.len(), 4 * 64 * 3);
        assert_eq!(qm.beta_idx.len(), 4 * 64 / D);
        // M levels × 1 bit (q=2) per entry + 2-bit β/block + f32 row scales
        let expect_bits = 4 * 64 * 3 + 2 * (4 * 64 / D) + 4 * 32;
        assert_eq!(qm.payload_bytes(), expect_bits / 8);
    }

    #[test]
    fn zero_row_roundtrip() {
        let hq = HierarchicalQuantizer::new(3, 3, vec![1.0]);
        let w = crate::util::linalg::Mat::zeros(2, 32);
        let qm = hq.quantize_matrix(&w);
        assert_eq!(qm.scales, vec![0.0, 0.0]);
        let deq = hq.dequantize_matrix(&qm);
        assert!(deq.data.iter().all(|&v| v == 0.0));
    }
}
