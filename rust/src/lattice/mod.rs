//! The Gosset-lattice (E8) engine at the core of NestQuant.
//!
//! * [`e8`] — closest-point oracles for E8 = D8 ∪ (D8 + ½) (paper Alg. 5),
//!   including the simplified NestQuantM decode oracle (Appendix D).
//! * [`voronoi`] — Voronoi codes (Conway & Sloane): Encode (Alg. 1) /
//!   Decode (Alg. 2) against the integer generator matrix of 2·E8 used by
//!   the paper's CUDA kernel (Appendix E).
//! * [`nested`] — the multi-β union-of-Voronoi-codebooks quantizer
//!   (Alg. 3), Opt-β / First-β strategies, and quantized dot products
//!   (Alg. 4).
//! * [`beta_dp`] — the dynamic program selecting the optimal β subset
//!   (Alg. 6, Appendix F).
//! * [`hierarchical`] — M-level hierarchical nested-lattice codes
//!   (Kaplan & Ordentlich, ISIT 2025): exact base-q digit expansion of
//!   Q_Λ(x), successive-refinement truncation, and the shared pair LUT
//!   behind the `quant::lut` GEMM backend.
//! * [`hex`] — a 2-D hexagonal (A2) nested-lattice demo used to regenerate
//!   Fig. 2's shaping-waste comparison.

pub mod beta_dp;
pub mod e8;
pub mod hex;
pub mod hierarchical;
pub mod nested;
pub mod voronoi;

pub use e8::{e8_contains, nearest_e8, nearest_e8_m, D};
pub use hierarchical::{HierarchicalCodec, HierarchicalQuantizer, PairLut};
pub use nested::{NestedLatticeQuantizer, QuantizedVector, Strategy};
pub use voronoi::VoronoiCodec;
