//! Quantized KV-cache manager — the serving-path store where keys and
//! values live in *coded* form (coset codes + β indices + scale), cutting
//! cache memory ~4× vs fp16 / ~8× vs fp32 (paper §1: the memory-bandwidth
//! bottleneck of generation).
//!
//! Layout: per layer, per head, append-only code arrays. Scoring decodes
//! keys on the fly (Algorithm 4-style: decode is integer, β/scale applied
//! per block), so the bytes touched per token scale with the quantized
//! payload.

use crate::lattice::nested::{NestedLatticeQuantizer, QuantizedVector};

/// Per-(layer, head) append-only quantized vector store.
#[derive(Default)]
pub struct QuantStore {
    entries: Vec<QuantizedVector>,
}

impl QuantStore {
    pub fn push(&mut self, qv: QuantizedVector) {
        self.entries.push(qv);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> &QuantizedVector {
        &self.entries[i]
    }

    pub fn payload_bytes(&self, q: u32) -> usize {
        self.entries
            .iter()
            .map(|e| e.payload_bits(q).div_ceil(8))
            .sum()
    }
}

/// KV cache for one generation stream: quantized (NestQuant) or fp32
/// (baseline), per layer × head.
pub enum KvCache {
    Fp {
        /// [layer][head] → (keys, values), each Vec<Vec<f32>> by position
        keys: Vec<Vec<Vec<Vec<f32>>>>,
        values: Vec<Vec<Vec<Vec<f32>>>>,
    },
    Nest {
        /// key / value quantizers (calibrated separately, §4.6 step 4)
        k_nq: NestedLatticeQuantizer,
        v_nq: NestedLatticeQuantizer,
        keys: Vec<Vec<QuantStore>>,
        values: Vec<Vec<QuantStore>>,
    },
}

impl KvCache {
    pub fn new_fp(n_layer: usize, n_head: usize) -> Self {
        KvCache::Fp {
            keys: vec![vec![Vec::new(); n_head]; n_layer],
            values: vec![vec![Vec::new(); n_head]; n_layer],
        }
    }

    pub fn new_nest(
        n_layer: usize,
        n_head: usize,
        k_nq: NestedLatticeQuantizer,
        v_nq: NestedLatticeQuantizer,
    ) -> Self {
        KvCache::Nest {
            k_nq,
            v_nq,
            keys: (0..n_layer)
                .map(|_| (0..n_head).map(|_| QuantStore::default()).collect())
                .collect(),
            values: (0..n_layer)
                .map(|_| (0..n_head).map(|_| QuantStore::default()).collect())
                .collect(),
        }
    }

    /// Append one position's K and V for (layer, head). Vectors are
    /// quantized on insertion in the Nest variant.
    pub fn append(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        match self {
            KvCache::Fp { keys, values } => {
                keys[layer][head].push(k.to_vec());
                values[layer][head].push(v.to_vec());
            }
            KvCache::Nest {
                k_nq,
                v_nq,
                keys,
                values,
            } => {
                keys[layer][head].push(k_nq.quantize(k));
                values[layer][head].push(v_nq.quantize(v));
            }
        }
    }

    /// Number of cached positions for a layer/head.
    pub fn seq_len(&self, layer: usize, head: usize) -> usize {
        match self {
            KvCache::Fp { keys, .. } => keys[layer][head].len(),
            KvCache::Nest { keys, .. } => keys[layer][head].len(),
        }
    }

    /// Decode (or fetch) the key at position `pos`.
    pub fn key(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        match self {
            KvCache::Fp { keys, .. } => keys[layer][head][pos].clone(),
            KvCache::Nest { k_nq, keys, .. } => k_nq.dequantize(keys[layer][head].get(pos)),
        }
    }

    /// Decode (or fetch) the value at position `pos`.
    pub fn value(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        match self {
            KvCache::Fp { values, .. } => values[layer][head][pos].clone(),
            KvCache::Nest { v_nq, values, .. } => v_nq.dequantize(values[layer][head].get(pos)),
        }
    }

    /// Attention scores q·k_t for every cached position (pre-softmax,
    /// unscaled). For the Nest variant the key decode runs on the coded
    /// form — the memory-bound path the paper optimizes.
    pub fn scores(&self, layer: usize, head: usize, qvec: &[f32], out: &mut Vec<f32>) {
        out.clear();
        match self {
            KvCache::Fp { keys, .. } => {
                for k in &keys[layer][head] {
                    out.push(crate::util::stats::dot(qvec, k) as f32);
                }
            }
            KvCache::Nest { k_nq, keys, .. } => {
                for i in 0..keys[layer][head].len() {
                    let k = k_nq.dequantize(keys[layer][head].get(i));
                    out.push(crate::util::stats::dot(qvec, &k) as f32);
                }
            }
        }
    }

    /// Total cache payload in bytes (the memory the paper's KV
    /// quantization saves).
    pub fn payload_bytes(&self) -> usize {
        match self {
            KvCache::Fp { keys, values } => {
                let count = |store: &Vec<Vec<Vec<Vec<f32>>>>| -> usize {
                    store
                        .iter()
                        .flatten()
                        .flatten()
                        .map(|v| v.len() * 4)
                        .sum()
                };
                count(keys) + count(values)
            }
            KvCache::Nest {
                k_nq, keys, values, ..
            } => {
                let q = k_nq.q();
                let count = |store: &Vec<Vec<QuantStore>>| -> usize {
                    store.iter().flatten().map(|s| s.payload_bytes(q)).sum()
                };
                count(keys) + count(values)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    fn nq() -> NestedLatticeQuantizer {
        NestedLatticeQuantizer::new(14, vec![0.25, 0.32, 0.45, 1.0])
    }

    #[test]
    fn append_and_score_roundtrip() {
        let mut rng = Rng::new(1701);
        let mut cache = KvCache::new_nest(2, 2, nq(), nq());
        let dh = 32;
        let mut keys = Vec::new();
        for _ in 0..10 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            cache.append(0, 1, &k, &v);
            keys.push(k);
        }
        assert_eq!(cache.seq_len(0, 1), 10);
        assert_eq!(cache.seq_len(0, 0), 0);
        let qv = rng.gauss_vec(dh);
        let mut scores = Vec::new();
        cache.scores(0, 1, &qv, &mut scores);
        assert_eq!(scores.len(), 10);
        for (i, &s) in scores.iter().enumerate() {
            let exact = stats::dot(&qv, &keys[i]) as f32;
            assert!(
                (s - exact).abs() < 0.35 * (1.0 + exact.abs()),
                "score {i}: {s} vs {exact}"
            );
        }
    }

    #[test]
    fn quantized_cache_smaller_than_fp() {
        let mut rng = Rng::new(1702);
        let mut fp = KvCache::new_fp(2, 2);
        let mut nest = KvCache::new_nest(2, 2, nq(), nq());
        let dh = 48;
        for _ in 0..50 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            for l in 0..2 {
                for h in 0..2 {
                    fp.append(l, h, &k, &v);
                    nest.append(l, h, &k, &v);
                }
            }
        }
        let fp_bytes = fp.payload_bytes();
        let nest_bytes = nest.payload_bytes();
        // fp32 = 32 bits/entry; NestQuant ≈ 4.3 + scale overhead → > 5×
        assert!(
            (nest_bytes as f64) < fp_bytes as f64 / 4.0,
            "cache compression too weak: {nest_bytes} vs {fp_bytes}"
        );
    }

    #[test]
    fn fp_cache_exact() {
        let mut rng = Rng::new(1703);
        let mut fp = KvCache::new_fp(1, 1);
        let k = rng.gauss_vec(16);
        let v = rng.gauss_vec(16);
        fp.append(0, 0, &k, &v);
        assert_eq!(fp.key(0, 0, 0), k);
        assert_eq!(fp.value(0, 0, 0), v);
    }
}
