//! Per-session KV-cache view — the serving-path store where keys and
//! values live in *coded* form (coset codes + β indices + scale), cutting
//! cache memory ~4× vs fp16 / ~8× vs fp32 (paper §1: the memory-bandwidth
//! bottleneck of generation).
//!
//! Since the paged-pool rework the coded storage lives in
//! [`crate::kvpool`]: every quantized cache is a [`SessionKv`] view over
//! a [`KvPool`] — pages of 16 positions × all (layer, head) lanes,
//! shared across sessions through the token-prefix index, evicted LRU
//! under a byte budget, with **per-layer** calibrated quantizer pairs
//! (§4.6 step 4). [`KvCache::new_nest`] keeps the old single-owner
//! constructor as a thin adapter: it builds a private single-session
//! pool, so tests and benches of the coded path need no pool plumbing.
//!
//! Hot paths ([`KvCache::scores`], [`KvCache::weighted_value_sum`])
//! stream page-by-page over the coded payload through the same
//! `DecodeConsts` integer decoder as the packed GEMM — per-position
//! `Vec<f32>` buffers never materialize on the decode path.

use crate::kvpool::{KvLayerQuant, KvPool, PoolConfig, SessionKv};
use crate::lattice::nested::NestedLatticeQuantizer;
use std::sync::Arc;

/// KV cache for one generation stream: fp32 (baseline) or a view over a
/// paged pool of quantized payloads (NestQuant).
pub enum KvCache {
    Fp {
        /// [layer][head] → (keys, values), each Vec<Vec<f32>> by position
        keys: Vec<Vec<Vec<Vec<f32>>>>,
        values: Vec<Vec<Vec<Vec<f32>>>>,
    },
    Pool(SessionKv),
}

impl KvCache {
    pub fn new_fp(n_layer: usize, n_head: usize) -> Self {
        KvCache::Fp {
            keys: vec![vec![Vec::new(); n_head]; n_layer],
            values: vec![vec![Vec::new(); n_head]; n_layer],
        }
    }

    /// Single-owner adapter: a private, unbudgeted pool with the same
    /// key/value quantizer pair replicated across layers (the pre-pool
    /// `Nest` behaviour, for tests/benches of the coded path).
    pub fn new_nest(
        n_layer: usize,
        n_head: usize,
        k_nq: NestedLatticeQuantizer,
        v_nq: NestedLatticeQuantizer,
    ) -> Self {
        let layers = (0..n_layer)
            .map(|_| KvLayerQuant {
                k: k_nq.clone(),
                v: v_nq.clone(),
            })
            .collect();
        let pool = Arc::new(KvPool::new(n_layer, n_head, layers, PoolConfig::default()));
        KvCache::Pool(SessionKv::new(pool))
    }

    /// A session view over a shared pool (the serving path).
    pub fn in_pool(pool: &Arc<KvPool>) -> Self {
        KvCache::Pool(SessionKv::new(pool.clone()))
    }

    /// Append one position's K and V for (layer, head). Vectors are
    /// quantized on insertion in the pooled variant (with that layer's
    /// own calibrated quantizers).
    pub fn append(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        match self {
            KvCache::Fp { keys, values } => {
                keys[layer][head].push(k.to_vec());
                values[layer][head].push(v.to_vec());
            }
            KvCache::Pool(sess) => sess.append(layer, head, k, v),
        }
    }

    /// Record the token that produced the position just appended on all
    /// lanes — this is what freezes completed pages and publishes them
    /// to the pool's prefix index. No-op for the fp32 baseline.
    pub fn note_token(&mut self, token: i32) {
        if let KvCache::Pool(sess) = self {
            sess.note_token(token);
        }
    }

    /// Map the longest cached prefix of `prompt` from the shared pool
    /// (zero quantization work for matched positions). Returns the
    /// number of positions served from shared pages; 0 for fp32.
    pub fn match_prefix(&mut self, prompt: &[i32]) -> usize {
        match self {
            KvCache::Fp { .. } => 0,
            KvCache::Pool(sess) => sess.match_prefix(prompt),
        }
    }

    /// Number of cached positions for a layer/head.
    pub fn seq_len(&self, layer: usize, head: usize) -> usize {
        match self {
            KvCache::Fp { keys, .. } => keys[layer][head].len(),
            KvCache::Pool(sess) => sess.seq_len(layer, head),
        }
    }

    /// Decode (or fetch) the key at position `pos`.
    pub fn key(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        match self {
            KvCache::Fp { keys, .. } => keys[layer][head][pos].clone(),
            KvCache::Pool(sess) => sess.key(layer, head, pos),
        }
    }

    /// Decode (or fetch) the value at position `pos`.
    pub fn value(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        match self {
            KvCache::Fp { values, .. } => values[layer][head][pos].clone(),
            KvCache::Pool(sess) => sess.value(layer, head, pos),
        }
    }

    /// Attention scores q·k_t for every cached position (pre-softmax,
    /// unscaled). The pooled variant streams page-by-page over the coded
    /// keys — all-integer block decode for M-variant codecs at q ≤ 16 —
    /// through fixed stack scratch; no per-key dequantization buffer.
    pub fn scores(&self, layer: usize, head: usize, qvec: &[f32], out: &mut Vec<f32>) {
        match self {
            KvCache::Fp { keys, .. } => {
                out.clear();
                for k in &keys[layer][head] {
                    out.push(crate::util::stats::dot(qvec, k) as f32);
                }
            }
            KvCache::Pool(sess) => sess.scores(layer, head, qvec, out),
        }
    }

    /// out = Σ_t probs[t]·v_t — the decode-step value path, streamed off
    /// the coded values with the same integer decoder as [`Self::scores`]
    /// (no per-position `Vec<f32>`). `out` is overwritten (head dim).
    pub fn weighted_value_sum(&self, layer: usize, head: usize, probs: &[f32], out: &mut [f32]) {
        match self {
            KvCache::Fp { values, .. } => {
                out.fill(0.0);
                let vals = &values[layer][head];
                assert!(probs.len() <= vals.len());
                for (t, &p) in probs.iter().enumerate() {
                    let vt = &vals[t];
                    for i in 0..out.len() {
                        out[i] += p * vt[i];
                    }
                }
            }
            KvCache::Pool(sess) => sess.weighted_value_sum(layer, head, probs, out),
        }
    }

    /// Total cache payload in bytes (the memory the paper's KV
    /// quantization saves). Pooled sessions report their mapped pages'
    /// full capacity cost — the honest paged-allocator number.
    pub fn payload_bytes(&self) -> usize {
        match self {
            KvCache::Fp { keys, values } => {
                let count = |store: &Vec<Vec<Vec<Vec<f32>>>>| -> usize {
                    store
                        .iter()
                        .flatten()
                        .flatten()
                        .map(|v| v.len() * 4)
                        .sum()
                };
                count(keys) + count(values)
            }
            KvCache::Pool(sess) => sess.payload_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    fn nq() -> NestedLatticeQuantizer {
        NestedLatticeQuantizer::new(14, vec![0.25, 0.32, 0.45, 1.0])
    }

    #[test]
    fn append_and_score_roundtrip() {
        let mut rng = Rng::new(1701);
        let mut cache = KvCache::new_nest(2, 2, nq(), nq());
        let dh = 32;
        let mut keys = Vec::new();
        for _ in 0..10 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            cache.append(0, 1, &k, &v);
            keys.push(k);
        }
        assert_eq!(cache.seq_len(0, 1), 10);
        assert_eq!(cache.seq_len(0, 0), 0);
        let qv = rng.gauss_vec(dh);
        let mut scores = Vec::new();
        cache.scores(0, 1, &qv, &mut scores);
        assert_eq!(scores.len(), 10);
        for (i, &s) in scores.iter().enumerate() {
            let exact = stats::dot(&qv, &keys[i]) as f32;
            assert!(
                (s - exact).abs() < 0.35 * (1.0 + exact.abs()),
                "score {i}: {s} vs {exact}"
            );
        }
    }

    #[test]
    fn streaming_scores_match_dequantized_reference() {
        // the page-streaming score path (integer decode for M-variant,
        // float for plain) must agree with dequantize-then-dot on the
        // same coded entries to float tolerance.
        let mut rng = Rng::new(1704);
        for m_variant in [false, true] {
            let betas = vec![0.25, 0.32, 0.45, 1.0];
            let nq = if m_variant {
                NestedLatticeQuantizer::new_m(14, betas)
            } else {
                NestedLatticeQuantizer::new(14, betas)
            };
            let mut cache = KvCache::new_nest(1, 1, nq.clone(), nq.clone());
            let dh = 32;
            for _ in 0..12 {
                let k = rng.gauss_vec(dh);
                let v = rng.gauss_vec(dh);
                cache.append(0, 0, &k, &v);
            }
            let qv = rng.gauss_vec(dh);
            let mut scores = Vec::new();
            cache.scores(0, 0, &qv, &mut scores);
            assert_eq!(scores.len(), 12);
            for (i, &s) in scores.iter().enumerate() {
                // cache.key() decodes the stored codes through the same
                // quantizer — the dequantize-then-dot reference
                let dec = cache.key(0, 0, i);
                let expect = stats::dot(&qv, &dec) as f32;
                assert!(
                    (s - expect).abs() < 1e-4 * (1.0 + expect.abs()),
                    "m_variant={m_variant} pos {i}: streaming {s} vs reference {expect}"
                );
            }
        }
    }

    #[test]
    fn weighted_value_sum_matches_per_position_loop() {
        let mut rng = Rng::new(1705);
        for m_variant in [false, true] {
            let betas = vec![0.25, 0.32, 0.45, 1.0];
            let nq = if m_variant {
                NestedLatticeQuantizer::new_m(14, betas)
            } else {
                NestedLatticeQuantizer::new(14, betas)
            };
            let dh = 24;
            let mut fp = KvCache::new_fp(1, 1);
            let mut nest = KvCache::new_nest(1, 1, nq.clone(), nq.clone());
            for _ in 0..19 {
                let k = rng.gauss_vec(dh);
                let v = rng.gauss_vec(dh);
                fp.append(0, 0, &k, &v);
                nest.append(0, 0, &k, &v);
            }
            let mut probs: Vec<f32> = (0..19).map(|_| rng.f32()).collect();
            let z: f32 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= z;
            }
            for cache in [&fp, &nest] {
                let mut fast = vec![0f32; dh];
                cache.weighted_value_sum(0, 0, &probs, &mut fast);
                // reference: the old per-position decode-into-Vec loop
                let mut slow = vec![0f32; dh];
                for (t, &p) in probs.iter().enumerate() {
                    let vt = cache.value(0, 0, t);
                    for i in 0..dh {
                        slow[i] += p * vt[i];
                    }
                }
                for i in 0..dh {
                    assert!(
                        (fast[i] - slow[i]).abs() < 1e-5 * (1.0 + slow[i].abs()),
                        "m={m_variant} i={i}: {} vs {}",
                        fast[i],
                        slow[i]
                    );
                }
            }
        }
    }

    #[test]
    fn quantized_cache_smaller_than_fp() {
        let mut rng = Rng::new(1702);
        let mut fp = KvCache::new_fp(2, 2);
        let mut nest = KvCache::new_nest(2, 2, nq(), nq());
        let dh = 48;
        for _ in 0..50 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            for l in 0..2 {
                for h in 0..2 {
                    fp.append(l, h, &k, &v);
                    nest.append(l, h, &k, &v);
                }
            }
        }
        let fp_bytes = fp.payload_bytes();
        let nest_bytes = nest.payload_bytes();
        // fp32 = 32 bits/entry; NestQuant ≈ 4.3 + scale overhead → > 4×
        // even with the tail page's unused capacity counted
        assert!(
            (nest_bytes as f64) < fp_bytes as f64 / 4.0,
            "cache compression too weak: {nest_bytes} vs {fp_bytes}"
        );
    }

    #[test]
    fn fp_cache_exact() {
        let mut rng = Rng::new(1703);
        let mut fp = KvCache::new_fp(1, 1);
        let k = rng.gauss_vec(16);
        let v = rng.gauss_vec(16);
        fp.append(0, 0, &k, &v);
        assert_eq!(fp.key(0, 0, 0), k);
        assert_eq!(fp.value(0, 0, 0), v);
    }
}
