//! Quantized KV-cache manager — the serving-path store where keys and
//! values live in *coded* form (coset codes + β indices + scale), cutting
//! cache memory ~4× vs fp16 / ~8× vs fp32 (paper §1: the memory-bandwidth
//! bottleneck of generation).
//!
//! Layout: per layer, per head, append-only code arrays. Scoring decodes
//! keys on the fly (Algorithm 4-style: decode is integer, β/scale applied
//! per block), so the bytes touched per token scale with the quantized
//! payload.

use crate::lattice::e8::D;
use crate::lattice::nested::{NestedLatticeQuantizer, QuantizedVector};

/// Per-(layer, head) append-only quantized vector store.
#[derive(Default)]
pub struct QuantStore {
    entries: Vec<QuantizedVector>,
}

impl QuantStore {
    pub fn push(&mut self, qv: QuantizedVector) {
        self.entries.push(qv);
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, i: usize) -> &QuantizedVector {
        &self.entries[i]
    }

    pub fn payload_bytes(&self, q: u32) -> usize {
        self.entries
            .iter()
            .map(|e| e.payload_bits(q).div_ceil(8))
            .sum()
    }
}

/// KV cache for one generation stream: quantized (NestQuant) or fp32
/// (baseline), per layer × head.
pub enum KvCache {
    Fp {
        /// [layer][head] → (keys, values), each Vec<Vec<f32>> by position
        keys: Vec<Vec<Vec<Vec<f32>>>>,
        values: Vec<Vec<Vec<Vec<f32>>>>,
    },
    Nest {
        /// key / value quantizers (calibrated separately, §4.6 step 4)
        k_nq: NestedLatticeQuantizer,
        v_nq: NestedLatticeQuantizer,
        keys: Vec<Vec<QuantStore>>,
        values: Vec<Vec<QuantStore>>,
    },
}

impl KvCache {
    pub fn new_fp(n_layer: usize, n_head: usize) -> Self {
        KvCache::Fp {
            keys: vec![vec![Vec::new(); n_head]; n_layer],
            values: vec![vec![Vec::new(); n_head]; n_layer],
        }
    }

    pub fn new_nest(
        n_layer: usize,
        n_head: usize,
        k_nq: NestedLatticeQuantizer,
        v_nq: NestedLatticeQuantizer,
    ) -> Self {
        KvCache::Nest {
            k_nq,
            v_nq,
            keys: (0..n_layer)
                .map(|_| (0..n_head).map(|_| QuantStore::default()).collect())
                .collect(),
            values: (0..n_layer)
                .map(|_| (0..n_head).map(|_| QuantStore::default()).collect())
                .collect(),
        }
    }

    /// Append one position's K and V for (layer, head). Vectors are
    /// quantized on insertion in the Nest variant.
    pub fn append(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        match self {
            KvCache::Fp { keys, values } => {
                keys[layer][head].push(k.to_vec());
                values[layer][head].push(v.to_vec());
            }
            KvCache::Nest {
                k_nq,
                v_nq,
                keys,
                values,
            } => {
                keys[layer][head].push(k_nq.quantize(k));
                values[layer][head].push(v_nq.quantize(v));
            }
        }
    }

    /// Number of cached positions for a layer/head.
    pub fn seq_len(&self, layer: usize, head: usize) -> usize {
        match self {
            KvCache::Fp { keys, .. } => keys[layer][head].len(),
            KvCache::Nest { keys, .. } => keys[layer][head].len(),
        }
    }

    /// Decode (or fetch) the key at position `pos`.
    pub fn key(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        match self {
            KvCache::Fp { keys, .. } => keys[layer][head][pos].clone(),
            KvCache::Nest { k_nq, keys, .. } => k_nq.dequantize(keys[layer][head].get(pos)),
        }
    }

    /// Decode (or fetch) the value at position `pos`.
    pub fn value(&self, layer: usize, head: usize, pos: usize) -> Vec<f32> {
        match self {
            KvCache::Fp { values, .. } => values[layer][head][pos].clone(),
            KvCache::Nest { v_nq, values, .. } => v_nq.dequantize(values[layer][head].get(pos)),
        }
    }

    /// Attention scores q·k_t for every cached position (pre-softmax,
    /// unscaled). For the Nest variant the key decode runs on the coded
    /// form — the memory-bound path the paper optimizes — streaming
    /// block-by-block through fixed stack scratch instead of
    /// materializing a dequantized `Vec<f32>` per key per token. With an
    /// M-variant codec the per-block decode is all-integer
    /// (`quant::qgemm::decode_block_i32`), so the bytes *and* the
    /// arithmetic touched per cached key stay on the quantized payload.
    pub fn scores(&self, layer: usize, head: usize, qvec: &[f32], out: &mut Vec<f32>) {
        out.clear();
        match self {
            KvCache::Fp { keys, .. } => {
                for k in &keys[layer][head] {
                    out.push(crate::util::stats::dot(qvec, k) as f32);
                }
            }
            KvCache::Nest { k_nq, keys, .. } => {
                let store = &keys[layer][head];
                let q = k_nq.q() as i32;
                // strength-reduced branch-free decode (magic-multiply
                // division) — the same hot-path decoder as the packed
                // GEMV; exact for q ≤ 16 (`magic_division_exact`)
                let use_int = k_nq.codec.m_variant && q <= 16;
                let consts = crate::quant::qgemm::DecodeConsts::new(q);
                let mut c = [0u8; D];
                let mut e = [0i32; D];
                for i in 0..store.len() {
                    let kv = store.get(i);
                    if kv.scale == 0.0 {
                        out.push(0.0);
                        continue;
                    }
                    debug_assert_eq!(kv.n, qvec.len());
                    let denorm = (kv.scale / (kv.n as f32).sqrt()) as f64;
                    let mut acc = 0f64;
                    for j in 0..kv.n / D {
                        c.copy_from_slice(&kv.codes[j * D..(j + 1) * D]);
                        let xb = &qvec[j * D..(j + 1) * D];
                        if use_int {
                            // integer decode in half units; β/2 applied
                            // per block, matching PackedNestMatrix
                            consts.decode(&c, &mut e);
                            let mut d = 0f32;
                            for ii in 0..D {
                                d += e[ii] as f32 * xb[ii];
                            }
                            acc += (d * 0.5 * k_nq.betas[kv.beta_idx[j] as usize]) as f64;
                        } else {
                            let rec = k_nq.decode_block(&c, kv.beta_idx[j]);
                            let mut d = 0f32;
                            for ii in 0..D {
                                d += rec[ii] * xb[ii];
                            }
                            acc += d as f64;
                        }
                    }
                    out.push((acc * denorm) as f32);
                }
            }
        }
    }

    /// Total cache payload in bytes (the memory the paper's KV
    /// quantization saves).
    pub fn payload_bytes(&self) -> usize {
        match self {
            KvCache::Fp { keys, values } => {
                let count = |store: &Vec<Vec<Vec<Vec<f32>>>>| -> usize {
                    store
                        .iter()
                        .flatten()
                        .flatten()
                        .map(|v| v.len() * 4)
                        .sum()
                };
                count(keys) + count(values)
            }
            KvCache::Nest {
                k_nq, keys, values, ..
            } => {
                let q = k_nq.q();
                let count = |store: &Vec<Vec<QuantStore>>| -> usize {
                    store.iter().flatten().map(|s| s.payload_bytes(q)).sum()
                };
                count(keys) + count(values)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    fn nq() -> NestedLatticeQuantizer {
        NestedLatticeQuantizer::new(14, vec![0.25, 0.32, 0.45, 1.0])
    }

    #[test]
    fn append_and_score_roundtrip() {
        let mut rng = Rng::new(1701);
        let mut cache = KvCache::new_nest(2, 2, nq(), nq());
        let dh = 32;
        let mut keys = Vec::new();
        for _ in 0..10 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            cache.append(0, 1, &k, &v);
            keys.push(k);
        }
        assert_eq!(cache.seq_len(0, 1), 10);
        assert_eq!(cache.seq_len(0, 0), 0);
        let qv = rng.gauss_vec(dh);
        let mut scores = Vec::new();
        cache.scores(0, 1, &qv, &mut scores);
        assert_eq!(scores.len(), 10);
        for (i, &s) in scores.iter().enumerate() {
            let exact = stats::dot(&qv, &keys[i]) as f32;
            assert!(
                (s - exact).abs() < 0.35 * (1.0 + exact.abs()),
                "score {i}: {s} vs {exact}"
            );
        }
    }

    #[test]
    fn streaming_scores_match_dequantized_reference() {
        // the block-streaming score path (integer decode for M-variant,
        // float for plain) must agree with dequantize-then-dot on the
        // same coded entries to float tolerance.
        let mut rng = Rng::new(1704);
        for m_variant in [false, true] {
            let betas = vec![0.25, 0.32, 0.45, 1.0];
            let nq = if m_variant {
                NestedLatticeQuantizer::new_m(14, betas)
            } else {
                NestedLatticeQuantizer::new(14, betas)
            };
            let mut cache = KvCache::new_nest(1, 1, nq.clone(), nq.clone());
            let dh = 32;
            for _ in 0..12 {
                let k = rng.gauss_vec(dh);
                let v = rng.gauss_vec(dh);
                cache.append(0, 0, &k, &v);
            }
            let qv = rng.gauss_vec(dh);
            let mut scores = Vec::new();
            cache.scores(0, 0, &qv, &mut scores);
            assert_eq!(scores.len(), 12);
            let KvCache::Nest { k_nq, keys, .. } = &cache else {
                unreachable!()
            };
            for (i, &s) in scores.iter().enumerate() {
                let dec = k_nq.dequantize(keys[0][0].get(i));
                let expect = stats::dot(&qv, &dec) as f32;
                assert!(
                    (s - expect).abs() < 1e-4 * (1.0 + expect.abs()),
                    "m_variant={m_variant} pos {i}: streaming {s} vs reference {expect}"
                );
            }
        }
    }

    #[test]
    fn quantized_cache_smaller_than_fp() {
        let mut rng = Rng::new(1702);
        let mut fp = KvCache::new_fp(2, 2);
        let mut nest = KvCache::new_nest(2, 2, nq(), nq());
        let dh = 48;
        for _ in 0..50 {
            let k = rng.gauss_vec(dh);
            let v = rng.gauss_vec(dh);
            for l in 0..2 {
                for h in 0..2 {
                    fp.append(l, h, &k, &v);
                    nest.append(l, h, &k, &v);
                }
            }
        }
        let fp_bytes = fp.payload_bytes();
        let nest_bytes = nest.payload_bytes();
        // fp32 = 32 bits/entry; NestQuant ≈ 4.3 + scale overhead → > 5×
        assert!(
            (nest_bytes as f64) < fp_bytes as f64 / 4.0,
            "cache compression too weak: {nest_bytes} vs {fp_bytes}"
        );
    }

    #[test]
    fn fp_cache_exact() {
        let mut rng = Rng::new(1703);
        let mut fp = KvCache::new_fp(1, 1);
        let k = rng.gauss_vec(16);
        let v = rng.gauss_vec(16);
        fp.append(0, 0, &k, &v);
        assert_eq!(fp.key(0, 0, 0), k);
        assert_eq!(fp.value(0, 0, 0), v);
    }
}
