//! `cargo bench` entry point (criterion is unavailable offline; this uses
//! util::bench's warmup+median harness). Covers:
//!
//! * the Table 4 GEMV comparison (fp32 / NestQuantM packed / int4)
//! * lattice primitive micro-benches (encode / decode / Alg. 4 dot)
//! * rotation and KV-cache hot paths
//!
//! Output is also captured by `make bench` into bench_output.txt.

use nestquant::lattice::nested::NestedLatticeQuantizer;
use nestquant::lattice::voronoi::VoronoiCodec;
use nestquant::quant::qgemm::{decode_block_i32, qdot_int, PackedNestMatrix};
use nestquant::quant::uniform::PackedInt4Matrix;
use nestquant::rotation::Rotation;
use nestquant::util::bench::{bench, black_box};
use nestquant::util::linalg::Mat;
use nestquant::util::Rng;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(800);
    let mut rng = Rng::new(42);
    println!("# nestquant benches (1 CPU core)\n");

    // --- lattice primitives ---
    let codec = VoronoiCodec::new(14);
    let blocks: Vec<[f32; 8]> = (0..4096)
        .map(|_| {
            let mut b = [0f32; 8];
            rng.fill_gauss(&mut b);
            b
        })
        .collect();
    let r = bench("e8 nearest-point oracle (4096 blocks)", budget, || {
        let mut acc = 0f32;
        for b in &blocks {
            acc += nestquant::lattice::nearest_e8(b)[0];
        }
        acc
    });
    println!("{}", r.report());
    println!(
        "  -> {:.1} M blocks/s ({:.1} M entries/s)",
        4096.0 / r.median.as_secs_f64() / 1e6,
        8.0 * 4096.0 / r.median.as_secs_f64() / 1e6
    );

    let codes: Vec<[u8; 8]> = blocks.iter().map(|b| codec.encode(b)).collect();
    let r = bench("voronoi encode (4096 blocks)", budget, || {
        let mut acc = 0u8;
        for b in &blocks {
            acc ^= codec.encode(b)[0];
        }
        acc
    });
    println!("{}", r.report());
    let r = bench("integer decode (4096 blocks)", budget, || {
        let mut acc = 0i32;
        for c in &codes {
            acc ^= decode_block_i32(c, 14)[0];
        }
        acc
    });
    println!("{}", r.report());
    println!(
        "  -> {:.1} M entries/s decoded",
        8.0 * 4096.0 / r.median.as_secs_f64() / 1e6
    );

    // --- Algorithm 4 quantized dot ---
    let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
    let a = rng.gauss_vec(4096);
    let b = rng.gauss_vec(4096);
    let qa = nq.quantize(&a);
    let qb = nq.quantize(&b);
    let r = bench("Alg.4 dot, 4096-dim (int path)", budget, || {
        qdot_int(&nq, &qa, &qb)
    });
    println!("{}", r.report());
    let r = bench("Alg.4 dot, 4096-dim (float path)", budget, || {
        nq.dot(&qa, &qb)
    });
    println!("{}", r.report());

    // --- Table 4: GEMV ---
    println!("\n## Table 4 analog: n=2048 GEMV");
    let n = 2048;
    let w = Mat::from_vec(n, n, rng.gauss_vec(n * n));
    let x = rng.gauss_vec(n);
    let packed = PackedNestMatrix::quantize(&w, &nq);
    let int4 = PackedInt4Matrix::quantize(&w);
    let mut y = vec![0f32; n];
    let r_fp = bench("fp32 GEMV", budget, || {
        for r in 0..n {
            let mut acc = 0f32;
            let row = &w.data[r * n..(r + 1) * n];
            for i in 0..n {
                acc += row[i] * x[i];
            }
            y[r] = acc;
        }
        y[0]
    });
    println!("{}", r_fp.report());
    let mut y2 = vec![0f32; n];
    let r_nest = bench("NestQuantM packed GEMV (4.25b)", budget, || {
        packed.gemv_into(&x, &mut y2);
        y2[0]
    });
    println!("{}", r_nest.report());
    let r_i4 = bench("int4 uniform GEMV", budget, || int4.gemv(&x)[0]);
    println!("{}", r_i4.report());
    println!(
        "  speedup vs fp32: NestQuantM {:.2}x, int4 {:.2}x",
        r_fp.median_us() / r_nest.median_us(),
        r_fp.median_us() / r_i4.median_us()
    );

    // --- rotations ---
    println!("\n## rotations");
    let rot = Rotation::random_hadamard(4096, &mut rng);
    let mut v = rng.gauss_vec(4096);
    let r = bench("randomized Hadamard, n=4096", budget, || {
        rot.apply(&mut v);
        v[0]
    });
    println!("{}", r.report());

    // --- KV cache append+score ---
    println!("\n## kv cache");
    let mut cache = nestquant::kvcache::KvCache::new_nest(1, 1, nq.clone(), nq.clone());
    for _ in 0..128 {
        let k = rng.gauss_vec(64);
        let vv = rng.gauss_vec(64);
        cache.append(0, 0, &k, &vv);
    }
    let q = rng.gauss_vec(64);
    let mut scores = Vec::new();
    let r = bench("quantized KV scores, 128 pos × 64 dim", budget, || {
        cache.scores(0, 0, &q, &mut scores);
        scores[0]
    });
    println!("{}", r.report());
    black_box(&scores);
}
