//! `cargo bench` entry point (criterion is unavailable offline; this uses
//! util::bench's warmup+median harness). Covers:
//!
//! * the Table 4 GEMV comparison (fp32 / NestQuantM packed / int4)
//! * the decode-amortized GEMM sweep: batch {1, 8, 32, 128} × threads
//!   {1, all cores}, against the per-column GEMV baseline
//! * lattice primitive micro-benches (encode / decode / Alg. 4 dot)
//! * rotation and KV-cache hot paths
//! * the multi-session serving sweep over the paged KV pool: sessions
//!   {1, 8, 32} × shared-prefix {0%, 50%, 90%}, reporting tokens/s,
//!   pool bytes and prefix hit rate
//! * the fused decode-batch sweep: sessions {1, 8, 32} × admission
//!   {all-at-once, staggered} through the token-level scheduler,
//!   reporting decode tok/s and mean fused batch occupancy
//! * the mixed-precision QuantPlan sweep: per-site rate split
//!   q∈{12,16} vs uniform q=14 at equal payload bytes
//! * the heterogeneous KV-lane sweep: all-nested vs fp-edge +
//!   nested-middle vs all-fp KV plans served through one pool
//!
//! * the hierarchical-LUT GEMM sweep: pair-LUT inner products
//!   (M ∈ {2,3,4} × q ∈ {2,3}) against the packed decode backend at the
//!   equal flat rate q_eff = q^M
//! * SIMD kernel tier sweeps: the packed-decode, int4 and LUT backends
//!   re-run per available dispatch tier (`quant::kernels::available()`)
//!   through the `*_with` entry points, so BENCH_gemm.json carries
//!   scalar-vs-SIMD rows on the same shapes. Every quantized record
//!   tags a `kernel` column (0 = scalar, 1 = avx2, 2 = neon; dispatched
//!   rows use the active tier's index)
//!
//! Sections are selectable by argument (`-- core` / `-- gemm` /
//! `-- serve` / `-- plan` / `-- kvmix`; no argument runs everything):
//! `make bench` captures the full output into bench_output.txt,
//! `make bench-gemm` / `make bench-serve` / `make bench-plan` /
//! `make bench-kvmix` run one section. The GEMV/GEMM suites (the core
//! table-4 sweep plus the LUT sweep) are serialized together as a
//! `{"suites": [...]}` document to BENCH_gemm.json — written ONCE by
//! `main` so the sections no longer clobber each other's output — the
//! serving sweep to BENCH_serve.json, the plan sweep to BENCH_plan.json
//! and the lane sweep to BENCH_kvmix.json at the repo root for cross-PR
//! perf tracking (schema: EXPERIMENTS.md §Perf / §Serving /
//! §Mixed-precision / §KV lanes / §LUT backend).

use nestquant::lattice::nested::NestedLatticeQuantizer;
use nestquant::lattice::voronoi::VoronoiCodec;
use nestquant::quant::gemm::GemmScratch;
use nestquant::quant::qgemm::{decode_block_i32, qdot_int, PackedNestMatrix};
use nestquant::quant::uniform::PackedInt4Matrix;
use nestquant::rotation::Rotation;
use nestquant::util::bench::{bench, black_box, write_suites_json, BenchSuite};
use nestquant::util::linalg::Mat;
use nestquant::util::Rng;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    const SECTIONS: [&str; 5] = ["core", "gemm", "serve", "plan", "kvmix"];
    if let Some(bad) = args.iter().find(|a| !SECTIONS.contains(&a.as_str())) {
        eprintln!("unknown bench section '{bad}' (available: {SECTIONS:?})");
        std::process::exit(2);
    }
    let run = |name: &str| args.is_empty() || args.iter().any(|a| a == name);
    // both GEMV/GEMM sections feed one multi-suite BENCH_gemm.json,
    // written once below instead of per-section (which clobbered)
    let mut gemm_suites: Vec<BenchSuite> = Vec::new();
    if run("core") {
        gemm_suites.push(core_benches());
    }
    if run("gemm") {
        gemm_suites.push(gemm_lut_benches());
    }
    if !gemm_suites.is_empty() {
        let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent")
            .join("BENCH_gemm.json");
        let refs: Vec<&BenchSuite> = gemm_suites.iter().collect();
        match write_suites_json(&json_path, &refs) {
            Ok(()) => println!(
                "\nwrote {} ({} suite(s), {} records)",
                json_path.display(),
                refs.len(),
                refs.iter().map(|s| s.len()).sum::<usize>()
            ),
            Err(e) => eprintln!("could not write {}: {e}", json_path.display()),
        }
    }
    if run("serve") {
        serve_benches();
    }
    if run("plan") {
        plan_benches();
    }
    if run("kvmix") {
        kvmix_benches();
    }
}

/// Hierarchical-LUT GEMM sweep (M ∈ {2,3,4} × q ∈ {2,3}): the pair-LUT
/// inner-product backend (`quant::lut`, activations encoded per call,
/// weights never decoded) against the packed decode backend at the
/// equal flat rate q_eff = q^M — decode baselines only where the packed
/// coset path serves them (q_eff ≤ 16). Records gemv (batch=1) and
/// single-thread gemm (batch=32) medians tagged with q / m_levels /
/// bits-per-entry; merged into BENCH_gemm.json's multi-suite document
/// next to the core table-4 suite.
fn gemm_lut_benches() -> BenchSuite {
    use nestquant::lattice::hierarchical::{lut_supported, HierarchicalQuantizer};
    use nestquant::quant::lut::{LutScratch, PackedLutMatrix};

    println!("\n## hierarchical-LUT GEMM: M × q sweep (n=512)");
    let budget = Duration::from_millis(300);
    let mut rng = Rng::new(0x117);
    let n = 512usize;
    let batch = 32usize;
    let w = Mat::from_vec(n, n, rng.gauss_vec(n * n));
    let x = rng.gauss_vec(n);
    let xt = Mat::from_vec(batch, n, rng.gauss_vec(batch * n));
    let betas = vec![0.25f32, 0.32, 0.45, 1.0];
    let mut suite = BenchSuite::new("lut");
    let mut scratch = LutScratch::new();
    let mut gscratch = GemmScratch::new();
    // dispatched rows ran on the process-wide active tier
    let kern_active = nestquant::quant::kernels::active().index() as f64;
    for &q in &[2u32, 3] {
        for &m in &[2usize, 3, 4] {
            if !lut_supported(q, m as u32) {
                println!("skipping q={q} M={m}: outside the i32 LUT accumulator window");
                continue;
            }
            let wq = HierarchicalQuantizer::new(q, m, betas.clone());
            let aq = HierarchicalQuantizer::new(q, m, betas.clone());
            let qm = wq.quantize_matrix(&w);
            let lut = PackedLutMatrix::from_quantized(&qm, &wq, aq);
            let bits = lut.bits_per_entry();
            let mut y = vec![0f32; n];
            let r = bench(&format!("lut gemv q={q} M={m}"), budget, || {
                lut.gemv_into(&x, &mut y, &mut scratch);
                y[0]
            });
            println!("{}  [{:.2} b/entry]", r.report(), bits);
            suite.push(
                &r,
                &[
                    ("q", q as f64),
                    ("m_levels", m as f64),
                    ("batch", 1.0),
                    ("threads", 1.0),
                    ("kernel", kern_active),
                    ("bits_per_entry", bits),
                ],
            );
            let mut yt = Mat::zeros(batch, n);
            let r = bench(&format!("lut gemm b={batch} q={q} M={m}"), budget, || {
                lut.gemm_into(&xt, &mut yt, 1, &mut scratch);
                yt.data[0]
            });
            println!("{}  [{:.2} µs/col]", r.report(), r.median_us() / batch as f64);
            suite.push(
                &r,
                &[
                    ("q", q as f64),
                    ("m_levels", m as f64),
                    ("batch", batch as f64),
                    ("threads", 1.0),
                    ("kernel", kern_active),
                    ("bits_per_entry", bits),
                ],
            );
            let q_eff = q.pow(m as u32);
            if q_eff <= 16 {
                let nq = NestedLatticeQuantizer::new_m(q_eff, betas.clone());
                let packed = PackedNestMatrix::quantize(&w, &nq);
                let mut y2 = vec![0f32; n];
                let r = bench(&format!("decode gemv q_eff={q_eff}"), budget, || {
                    packed.gemv_into(&x, &mut y2);
                    y2[0]
                });
                println!("{}", r.report());
                suite.push(
                    &r,
                    &[
                        ("q", q_eff as f64),
                        ("m_levels", 1.0),
                        ("batch", 1.0),
                        ("threads", 1.0),
                        ("kernel", kern_active),
                    ],
                );
                let mut yt2 = Mat::zeros(batch, n);
                let r = bench(
                    &format!("decode gemm b={batch} q_eff={q_eff}"),
                    budget,
                    || {
                        packed.gemm_into(&xt, &mut yt2, 1, &mut gscratch);
                        yt2.data[0]
                    },
                );
                println!("{}  [{:.2} µs/col]", r.report(), r.median_us() / batch as f64);
                suite.push(
                    &r,
                    &[
                        ("q", q_eff as f64),
                        ("m_levels", 1.0),
                        ("batch", batch as f64),
                        ("threads", 1.0),
                        ("kernel", kern_active),
                    ],
                );
            } else {
                println!(
                    "  (no decode baseline at q={q} M={m}: packed coset codes cap \
                     q_eff at 16, q^M = {q_eff})"
                );
            }
        }
    }

    // --- LUT kernel tier sweep (q=2, M=3): the gathered accum path
    //     forced per tier via `gemm_into_with` ---
    println!("\n## LUT SIMD kernel tiers (q=2, M=3, b={batch}, 1 thread)");
    let wq = HierarchicalQuantizer::new(2, 3, betas.clone());
    let aq = HierarchicalQuantizer::new(2, 3, betas.clone());
    let lut = PackedLutMatrix::from_quantized(&wq.quantize_matrix(&w), &wq, aq);
    let mut yt = Mat::zeros(batch, n);
    for kern in nestquant::quant::kernels::available() {
        let r = bench(
            &format!("lut gemm b={batch} q=2 M=3 kernel={}", kern.name()),
            budget,
            || {
                lut.gemm_into_with(kern, &xt, &mut yt, 1, &mut scratch);
                yt.data[0]
            },
        );
        println!("{}", r.report());
        suite.push(
            &r,
            &[
                ("q", 2.0),
                ("m_levels", 3.0),
                ("batch", batch as f64),
                ("threads", 1.0),
                ("kernel", kern.index() as f64),
            ],
        );
    }
    suite
}

fn core_benches() -> BenchSuite {
    let budget = Duration::from_millis(800);
    let mut rng = Rng::new(42);
    println!("# nestquant benches (1 CPU core)\n");

    // --- lattice primitives ---
    let codec = VoronoiCodec::new(14);
    let blocks: Vec<[f32; 8]> = (0..4096)
        .map(|_| {
            let mut b = [0f32; 8];
            rng.fill_gauss(&mut b);
            b
        })
        .collect();
    let r = bench("e8 nearest-point oracle (4096 blocks)", budget, || {
        let mut acc = 0f32;
        for b in &blocks {
            acc += nestquant::lattice::nearest_e8(b)[0];
        }
        acc
    });
    println!("{}", r.report());
    println!(
        "  -> {:.1} M blocks/s ({:.1} M entries/s)",
        4096.0 / r.median.as_secs_f64() / 1e6,
        8.0 * 4096.0 / r.median.as_secs_f64() / 1e6
    );

    let codes: Vec<[u8; 8]> = blocks.iter().map(|b| codec.encode(b)).collect();
    let r = bench("voronoi encode (4096 blocks)", budget, || {
        let mut acc = 0u8;
        for b in &blocks {
            acc ^= codec.encode(b)[0];
        }
        acc
    });
    println!("{}", r.report());
    let r = bench("integer decode (4096 blocks)", budget, || {
        let mut acc = 0i32;
        for c in &codes {
            acc ^= decode_block_i32(c, 14)[0];
        }
        acc
    });
    println!("{}", r.report());
    println!(
        "  -> {:.1} M entries/s decoded",
        8.0 * 4096.0 / r.median.as_secs_f64() / 1e6
    );

    // --- Algorithm 4 quantized dot ---
    let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
    let a = rng.gauss_vec(4096);
    let b = rng.gauss_vec(4096);
    let qa = nq.quantize(&a);
    let qb = nq.quantize(&b);
    let r = bench("Alg.4 dot, 4096-dim (int path)", budget, || {
        qdot_int(&nq, &qa, &qb)
    });
    println!("{}", r.report());
    let r = bench("Alg.4 dot, 4096-dim (float path)", budget, || {
        nq.dot(&qa, &qb)
    });
    println!("{}", r.report());

    // --- Table 4: GEMV ---
    println!("\n## Table 4 analog: n=2048 GEMV");
    let n = 2048;
    let w = Mat::from_vec(n, n, rng.gauss_vec(n * n));
    let x = rng.gauss_vec(n);
    let packed = PackedNestMatrix::quantize(&w, &nq);
    let int4 = PackedInt4Matrix::quantize(&w);
    let mut y = vec![0f32; n];
    let r_fp = bench("fp32 GEMV", budget, || {
        for r in 0..n {
            let mut acc = 0f32;
            let row = &w.data[r * n..(r + 1) * n];
            for i in 0..n {
                acc += row[i] * x[i];
            }
            y[r] = acc;
        }
        y[0]
    });
    println!("{}", r_fp.report());
    let mut y2 = vec![0f32; n];
    let r_nest = bench("NestQuantM packed GEMV (4.25b)", budget, || {
        packed.gemv_into(&x, &mut y2);
        y2[0]
    });
    println!("{}", r_nest.report());
    let mut y3 = vec![0f32; n];
    let r_i4 = bench("int4 uniform GEMV", budget, || {
        // allocation-free comparator: a per-call Vec would skew the
        // NestQuantM-vs-int4 runtime comparison
        int4.gemv_into(&x, &mut y3);
        y3[0]
    });
    println!("{}", r_i4.report());
    println!(
        "  speedup vs fp32: NestQuantM {:.2}x, int4 {:.2}x",
        r_fp.median_us() / r_nest.median_us(),
        r_fp.median_us() / r_i4.median_us()
    );

    let mut suite = BenchSuite::new("table4_gemv_gemm_n2048");
    // dispatched rows ran on the process-wide active tier
    let kern_active = nestquant::quant::kernels::active().index() as f64;
    suite.push(&r_fp, &[("batch", 1.0), ("threads", 1.0), ("per_col_us", r_fp.median_us())]);
    suite.push(
        &r_nest,
        &[
            ("batch", 1.0),
            ("threads", 1.0),
            ("kernel", kern_active),
            ("per_col_us", r_nest.median_us()),
        ],
    );
    suite.push(
        &r_i4,
        &[
            ("batch", 1.0),
            ("threads", 1.0),
            ("kernel", kern_active),
            ("per_col_us", r_i4.median_us()),
        ],
    );

    // --- decode-amortized GEMM sweep (the tentpole claim: amortizing the
    //     8-block decode over a batch beats per-column GEMV ≥ 3× at
    //     batch ≥ 32, before threading even enters) ---
    println!("\n## decode-amortized GEMM (n=2048): batch × threads sweep");
    let n_threads = std::thread::available_parallelism().map_or(1, |v| v.get());
    let sweep_budget = Duration::from_millis(400);
    let mut scratch = GemmScratch::new();
    let mut amortization_checked = false;
    let mut amortization_ok = true;
    for &batch in &[1usize, 8, 32, 128] {
        let xt = Mat::from_vec(batch, n, rng.gauss_vec(batch * n));
        let r_loop = bench(&format!("gemv ×{batch} (per-column)"), sweep_budget, || {
            for c in 0..batch {
                packed.gemv_into(xt.row(c), &mut y2);
            }
            y2[0]
        });
        println!("{}  [{:.2} µs/col]", r_loop.report(), r_loop.median_us() / batch as f64);
        suite.push(
            &r_loop,
            &[
                ("batch", batch as f64),
                ("threads", 1.0),
                ("kernel", kern_active),
                ("per_col_us", r_loop.median_us() / batch as f64),
            ],
        );
        let mut thread_opts = vec![1usize];
        if n_threads > 1 {
            thread_opts.push(n_threads);
        }
        let mut yt = Mat::zeros(batch, n);
        for &threads in &thread_opts {
            let r = bench(&format!("gemm_into b={batch} t={threads}"), sweep_budget, || {
                packed.gemm_into(&xt, &mut yt, threads, &mut scratch);
                yt.data[0]
            });
            println!("{}  [{:.2} µs/col]", r.report(), r.median_us() / batch as f64);
            if threads == 1 && batch >= 8 {
                let ratio = r_loop.median_us() / r.median_us();
                println!("    decode amortization vs per-column gemv: {ratio:.2}x");
                if batch >= 32 {
                    amortization_checked = true;
                    amortization_ok &= ratio >= 3.0;
                }
            }
            suite.push(
                &r,
                &[
                    ("batch", batch as f64),
                    ("threads", threads as f64),
                    ("kernel", kern_active),
                    ("per_col_us", r.median_us() / batch as f64),
                ],
            );
        }
        let mut yt4 = Mat::zeros(batch, n);
        let r4 = bench(&format!("int4 gemm_into b={batch} t=1"), sweep_budget, || {
            int4.gemm_into(&xt, &mut yt4, 1, &mut scratch);
            yt4.data[0]
        });
        println!("{}  [{:.2} µs/col]", r4.report(), r4.median_us() / batch as f64);
        suite.push(
            &r4,
            &[
                ("batch", batch as f64),
                ("threads", 1.0),
                ("kernel", kern_active),
                ("per_col_us", r4.median_us() / batch as f64),
            ],
        );
    }

    // --- SIMD kernel tier sweep: the same packed/int4 shapes, but the
    //     dispatch tier forced per row via the `*_with` entry points, so
    //     one bench run carries scalar-vs-SIMD deltas regardless of the
    //     host's active tier ---
    println!("\n## SIMD kernel tiers (n=2048): scalar vs dispatched");
    let tier_batch = 32usize;
    let xt_tier = Mat::from_vec(tier_batch, n, rng.gauss_vec(tier_batch * n));
    let mut yt_tier = Mat::zeros(tier_batch, n);
    for kern in nestquant::quant::kernels::available() {
        let kname = kern.name();
        let kidx = kern.index() as f64;
        let r = bench(&format!("nest gemv kernel={kname}"), sweep_budget, || {
            packed.gemv_into_with(kern, &x, &mut y2);
            y2[0]
        });
        println!("{}", r.report());
        suite.push(
            &r,
            &[("batch", 1.0), ("threads", 1.0), ("kernel", kidx), ("per_col_us", r.median_us())],
        );
        let r = bench(
            &format!("nest gemm b={tier_batch} t=1 kernel={kname}"),
            sweep_budget,
            || {
                packed.gemm_into_with(kern, &xt_tier, &mut yt_tier, 1, &mut scratch);
                yt_tier.data[0]
            },
        );
        println!("{}  [{:.2} µs/col]", r.report(), r.median_us() / tier_batch as f64);
        suite.push(
            &r,
            &[
                ("batch", tier_batch as f64),
                ("threads", 1.0),
                ("kernel", kidx),
                ("per_col_us", r.median_us() / tier_batch as f64),
            ],
        );
        let r = bench(
            &format!("int4 gemm b={tier_batch} t=1 kernel={kname}"),
            sweep_budget,
            || {
                int4.gemm_into_with(kern, &xt_tier, &mut yt_tier, 1, &mut scratch);
                yt_tier.data[0]
            },
        );
        println!("{}  [{:.2} µs/col]", r.report(), r.median_us() / tier_batch as f64);
        suite.push(
            &r,
            &[
                ("batch", tier_batch as f64),
                ("threads", 1.0),
                ("kernel", kidx),
                ("per_col_us", r.median_us() / tier_batch as f64),
            ],
        );
    }
    println!(
        "\namortization acceptance (gemm_into ≥ 3x per-column gemv at batch ≥ 32, 1 thread): {}",
        if amortization_checked && amortization_ok {
            "PASS"
        } else {
            "FAIL"
        }
    );

    // --- rotations ---
    println!("\n## rotations");
    let rot = Rotation::random_hadamard(4096, &mut rng);
    let mut v = rng.gauss_vec(4096);
    let r = bench("randomized Hadamard, n=4096", budget, || {
        rot.apply(&mut v);
        v[0]
    });
    println!("{}", r.report());

    // --- KV cache append+score ---
    println!("\n## kv cache");
    let mut cache = nestquant::kvpool::SessionKv::solo(
        1,
        1,
        nestquant::kvpool::KvLaneCodec::Nested { k: nq.clone(), v: nq.clone() },
    );
    for _ in 0..128 {
        let k = rng.gauss_vec(64);
        let vv = rng.gauss_vec(64);
        cache.append(0, 0, &k, &vv);
    }
    let q = rng.gauss_vec(64);
    let mut scores = Vec::new();
    let r = bench("quantized KV scores, 128 pos × 64 dim", budget, || {
        cache.scores(0, 0, &q, &mut scores);
        scores[0]
    });
    println!("{}", r.report());
    let probs = vec![1.0 / 128.0; 128];
    let mut wsum = vec![0f32; 64];
    let r = bench("KV weighted value sum, 128 pos × 64 dim", budget, || {
        cache.weighted_value_sum(0, 0, &probs, &mut wsum);
        wsum[0]
    });
    println!("{}", r.report());
    black_box(&scores);
    suite
}

/// Multi-session serving over the shared paged KV pool: sessions
/// {1, 8, 32} × shared-prefix {0%, 50%, 90%} on a synthetic NestQuantM
/// W+KV engine. Each iteration serves the whole session set against a
/// fresh pool, so prefix hits are exactly the within-set sharing (the
/// first session misses, later ones map the common pages). Reports
/// tokens/s, the pool's post-serve byte footprint, and the prefix hit
/// rate; serialized to BENCH_serve.json. A second sweep drives the
/// fused token-level scheduler end-to-end (sessions × admission
/// pattern) and records decode tok/s, fused batch occupancy, preemption
/// counts and TTFT / inter-token p50/p99 (from the server's bounded
/// latency histograms) into the same file. Small shapes throughout, so
/// `make ci` runs the whole section as a scheduler smoke test.
fn serve_benches() {
    use nestquant::coordinator::generator::GenSession;
    use nestquant::coordinator::{BatchPolicy, Request, Server, ServerConfig};
    use nestquant::kvpool::{PoolConfig, PoolStats};
    use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
    use nestquant::model::weights::ModelWeights;
    use std::sync::Arc;

    println!("\n## multi-session serving: paged KV pool sweep");
    let cfg = nestquant::model::ModelConfig {
        vocab: 64,
        ctx: 96,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
    };
    let w = ModelWeights::synthetic(cfg, 0x5E12E);
    let eng = Arc::new(Engine::build(
        &w,
        EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        },
    ));
    let mut suite = BenchSuite::new("serve_multisession_pool");
    let budget = Duration::from_millis(600);
    let prompt_len = 40usize;
    let n_new = 8usize;
    for &sessions in &[1usize, 8, 32] {
        for &share in &[0.0f64, 0.5, 0.9] {
            let shared = (prompt_len as f64 * share).round() as usize;
            // prompts: a common `shared`-token prefix + distinct tails
            let prompts: Vec<Vec<i32>> = (0..sessions)
                .map(|s| {
                    let mut p: Vec<i32> =
                        (0..shared as i32).map(|i| (i * 3 + 1) % 64).collect();
                    p.extend(
                        (shared..prompt_len)
                            .map(|i| (i as i32 * 7 + 11 * (s as i32 + 1)) % 64),
                    );
                    p
                })
                .collect();
            let last_stats = std::cell::Cell::new(PoolStats::default());
            let r = bench(
                &format!("serve s={sessions} share={:.0}%", share * 100.0),
                budget,
                || {
                    let pool = eng.kv_pool(PoolConfig::default());
                    let mut total = 0usize;
                    for p in &prompts {
                        let mut sess = GenSession::new_in_pool(&eng, &pool);
                        let mut logits = sess.prefill(p);
                        for _ in 0..n_new {
                            let next = GenSession::greedy(&logits);
                            logits = sess.step(next);
                        }
                        total += p.len() + n_new;
                    }
                    last_stats.set(pool.stats());
                    total
                },
            );
            let st = last_stats.get();
            let toks = sessions * (prompt_len + n_new);
            let tok_s = toks as f64 / r.median.as_secs_f64();
            println!(
                "{}  [{:.0} tok/s, pool {:.1} KiB, prefix hit rate {:.2}]",
                r.report(),
                tok_s,
                st.bytes_in_use as f64 / 1024.0,
                st.prefix_hit_rate()
            );
            suite.push(
                &r,
                &[
                    ("sessions", sessions as f64),
                    ("share", share),
                    ("tok_s", tok_s),
                    ("pool_bytes", st.bytes_in_use as f64),
                    ("pages_in_use", st.pages_in_use as f64),
                    ("hit_rate", st.prefix_hit_rate()),
                ],
            );
        }
    }
    // --- fused decode-batch sweep: the token-level scheduler ---
    // Every live session's current token rides one activation panel per
    // layer through the packed GEMM ([`Server`]'s fused loop); the sweep
    // crosses batch size with admission pattern. `batch` submits every
    // request before the loop starts; `staggered` submits half, then the
    // rest as soon as the first streamed token proves decode is running —
    // token-level admission must merge them mid-flight without a barrier.
    println!("\n## fused decode batching: sessions × admission sweep");
    let fused_budget = Duration::from_millis(300);
    let n_new_fused = 8usize;
    for &sessions in &[1usize, 8, 32] {
        let prompts: Vec<Vec<i32>> = (0..sessions)
            .map(|s| {
                let mut p: Vec<i32> = (0..20).map(|i| (i * 3 + 1) % 64).collect();
                p.extend((0..4).map(|i| (i * 7 + 11 * (s as i32 + 1)) % 64));
                p
            })
            .collect();
        for &staggered in &[false, true] {
            let last = std::cell::Cell::new((0u64, 0u64, 0u64));
            // TTFT / inter-token percentiles from the server's bounded
            // latency histograms (last iteration's server)
            let lat = std::cell::Cell::new((0f64, 0f64, 0f64, 0f64));
            let label = format!(
                "fused decode s={sessions} admission={}",
                if staggered { "staggered" } else { "batch" }
            );
            let r = bench(&label, fused_budget, || {
                let (srv, rx) = Server::start(
                    eng.clone(),
                    ServerConfig {
                        policy: BatchPolicy {
                            max_batch: 8,
                            max_wait: Duration::from_millis(1),
                        },
                        stream: staggered,
                        ..ServerConfig::default()
                    },
                );
                let first = if staggered { sessions.div_ceil(2) } else { sessions };
                for (id, p) in prompts.iter().take(first).enumerate() {
                    srv.submit(Request::Generate {
                        id: id as u64,
                        prompt: p.clone(),
                        n_new: n_new_fused,
                    })
                    .expect("submit");
                }
                let mut submitted = first;
                let mut finals = 0usize;
                while finals < sessions {
                    let resp = rx.recv().expect("worker died");
                    if resp.done {
                        finals += 1;
                    }
                    // second wave joins while the first is mid-decode
                    while submitted < sessions {
                        srv.submit(Request::Generate {
                            id: submitted as u64,
                            prompt: prompts[submitted].clone(),
                            n_new: n_new_fused,
                        })
                        .expect("submit");
                        submitted += 1;
                    }
                }
                let (steps, dtoks) = srv.metrics.decode_stats();
                last.set((steps, dtoks, srv.metrics.preemptions()));
                let ttft = srv.metrics.ttft_summary();
                let itl = srv.metrics.inter_token_summary();
                lat.set((ttft.p50_ms, ttft.p99_ms, itl.p50_ms, itl.p99_ms));
                srv.shutdown();
                sessions * n_new_fused
            });
            let (steps, dtoks, preempt) = last.get();
            let (ttft_p50, ttft_p99, itl_p50, itl_p99) = lat.get();
            let decode_tok_s = (sessions * n_new_fused) as f64 / r.median.as_secs_f64();
            let mean_batch = if steps > 0 { dtoks as f64 / steps as f64 } else { 0.0 };
            println!(
                "{}  [{:.0} decode tok/s, mean fused batch {:.2}, preemptions {}, \
                 ttft p50/p99 {:.1}/{:.1} ms, itl p50/p99 {:.2}/{:.2} ms]",
                r.report(),
                decode_tok_s,
                mean_batch,
                preempt,
                ttft_p50,
                ttft_p99,
                itl_p50,
                itl_p99
            );
            suite.push(
                &r,
                &[
                    ("sessions", sessions as f64),
                    ("staggered", if staggered { 1.0 } else { 0.0 }),
                    ("decode_tok_s", decode_tok_s),
                    ("mean_decode_batch", mean_batch),
                    ("preemptions", preempt as f64),
                    ("ttft_p50_ms", ttft_p50),
                    ("ttft_p99_ms", ttft_p99),
                    ("itl_p50_ms", itl_p50),
                    ("itl_p99_ms", itl_p99),
                ],
            );
        }
    }

    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_serve.json");
    match suite.write_json(&json_path) {
        Ok(()) => println!("wrote {} ({} records)", json_path.display(), suite.len()),
        Err(e) => eprintln!("could not write {}: {e}", json_path.display()),
    }
}

/// Mixed-precision QuantPlan sweep (the per-site policy API): a
/// sensitive-site rate split (q=16 on `down`/`o`, q=12 elsewhere)
/// against uniform q∈{12,14,16} on a synthetic NestQuantM weights-only
/// engine. Because coset codes pack at ⌈log2 q⌉ bits, q ∈ {12, 14, 16}
/// all store 4 bits/entry — the split costs the *same payload bytes* as
/// uniform q=14 while spending fidelity where the Hessians are worst.
/// Reports ppl, total weight payload and prefill latency per variant;
/// serialized to BENCH_plan.json.
fn plan_benches() {
    use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
    use nestquant::model::weights::ModelWeights;
    use nestquant::quant::plan::{EngineBuilder, PolicyPatch, QuantPlan, SiteKind};

    println!("\n## mixed-precision QuantPlan sweep (equal-payload rate split)");
    let cfg = nestquant::model::ModelConfig {
        vocab: 48,
        ctx: 32,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
    };
    let w = ModelWeights::synthetic(cfg, 0x9A17);
    let base = |q: u32| EngineOptions {
        method: Method::NestQuantM,
        regime: Regime::W,
        q,
        calib_windows: 2,
        ..Default::default()
    };
    let variants: Vec<(&str, QuantPlan)> = vec![
        ("uniform_q14", EngineBuilder::from_options(base(14)).plan()),
        (
            "split_q12_q16",
            EngineBuilder::from_options(base(12))
                .site(SiteKind::Down, PolicyPatch::rate(16))
                .site(SiteKind::O, PolicyPatch::rate(16))
                .plan(),
        ),
        ("uniform_q12", EngineBuilder::from_options(base(12)).plan()),
        ("uniform_q16", EngineBuilder::from_options(base(16)).plan()),
    ];
    let mut suite = BenchSuite::new("quantplan_rate_split");
    let budget = Duration::from_millis(400);
    let toks: Vec<i32> = w.val_tokens[..cfg.ctx].to_vec();
    let mut payloads = Vec::new();
    for (vi, (name, plan)) in variants.iter().enumerate() {
        let eng = Engine::build_plan(&w, plan.clone());
        let payload: usize = eng.site_payloads().iter().map(|s| s.bytes).sum();
        let ppl = eng.eval_ppl(&w.val_tokens, 3);
        let r = bench(&format!("prefill {name}"), budget, || {
            eng.forward_window(&toks).data[0]
        });
        println!(
            "{}  [ppl {:.4}, weights {:.1} KiB, mean {:.2} b/entry]",
            r.report(),
            ppl,
            payload as f64 / 1024.0,
            eng.weight_bits_packed
        );
        payloads.push(payload);
        suite.push(
            &r,
            &[
                ("variant", vi as f64),
                ("ppl", ppl),
                ("payload_bytes", payload as f64),
                ("bits_packed", eng.weight_bits_packed),
            ],
        );
    }
    // acceptance: the split ships the same bytes as uniform q=14
    let drift =
        (payloads[1] as f64 - payloads[0] as f64).abs() / payloads[0].max(1) as f64;
    println!(
        "\nequal-payload acceptance (split_q12_q16 vs uniform_q14 within 1%): {}",
        if drift <= 0.01 { "PASS" } else { "FAIL" }
    );
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_plan.json");
    match suite.write_json(&json_path) {
        Ok(()) => println!("wrote {} ({} records)", json_path.display(), suite.len()),
        Err(e) => eprintln!("could not write {}: {e}", json_path.display()),
    }
}

/// Heterogeneous KV-lane sweep: three KV plans on a 3-layer synthetic
/// NestQuantM W+KV engine — all-nested lanes, fp32 first/last layers +
/// nested middle (the "keep the sensitive edges exact" deployment), and
/// all-fp lanes — each served through one shared pool with 8 sessions
/// sharing a 50% prompt prefix. Reports tokens/s, the pool's byte
/// footprint (with the per-class split) and prefix hit rate per
/// variant; serialized to BENCH_kvmix.json. Cheap enough that `make ci`
/// runs it as a smoke test of the mixed-lane serving path.
fn kvmix_benches() {
    use nestquant::coordinator::generator::GenSession;
    use nestquant::kvpool::{PoolConfig, PoolStats};
    use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
    use nestquant::model::weights::ModelWeights;
    use nestquant::quant::plan::{PolicyPatch, QuantPlan, SiteRole, SiteSelector};

    println!("\n## heterogeneous KV lanes: plan-mix sweep");
    let cfg = nestquant::model::ModelConfig {
        vocab: 64,
        ctx: 64,
        d_model: 32,
        n_layer: 3,
        n_head: 2,
        d_ff: 64,
    };
    let w = ModelWeights::synthetic(cfg, 0x5A4E5);
    let base = QuantPlan::uniform(EngineOptions {
        method: Method::NestQuantM,
        regime: Regime::WKv,
        calib_windows: 1,
        ..Default::default()
    });
    let kv_fp = |lo: usize, hi: usize| {
        (
            SiteSelector {
                layers: Some((lo, hi)),
                role: Some(SiteRole::Kv),
                ..Default::default()
            },
            PolicyPatch::fp(),
        )
    };
    let mut edges = base.clone();
    edges.rules.push(kv_fp(0, 0));
    edges.rules.push(kv_fp(2, 2));
    let mut all_fp = base.clone();
    all_fp.rules.push((
        SiteSelector {
            role: Some(SiteRole::Kv),
            ..Default::default()
        },
        PolicyPatch::fp(),
    ));
    let variants: Vec<(&str, QuantPlan)> = vec![
        ("all_nested", base),
        ("fp_edges_nested_middle", edges),
        ("all_fp_kv", all_fp),
    ];

    let mut suite = BenchSuite::new("kvmix_lane_sweep");
    let budget = Duration::from_millis(300);
    let (sessions, prompt_len, shared, n_new) = (8usize, 32usize, 16usize, 8usize);
    let prompts: Vec<Vec<i32>> = (0..sessions)
        .map(|s| {
            let mut p: Vec<i32> = (0..shared as i32).map(|i| (i * 3 + 1) % 64).collect();
            p.extend(
                (shared..prompt_len).map(|i| (i as i32 * 7 + 11 * (s as i32 + 1)) % 64),
            );
            p
        })
        .collect();
    for (vi, (name, plan)) in variants.iter().enumerate() {
        let eng = Engine::build_plan(&w, plan.clone());
        let last_stats = std::cell::Cell::new(PoolStats::default());
        let r = bench(&format!("kvmix {name}"), budget, || {
            let pool = eng.kv_pool(PoolConfig::default());
            let mut total = 0usize;
            for p in &prompts {
                let mut sess = GenSession::new_in_pool(&eng, &pool);
                let mut logits = sess.prefill(p);
                for _ in 0..n_new {
                    let next = GenSession::greedy(&logits);
                    logits = sess.step(next);
                }
                total += p.len() + n_new;
            }
            last_stats.set(pool.stats());
            total
        });
        let st = last_stats.get();
        let toks = sessions * (prompt_len + n_new);
        let tok_s = toks as f64 / r.median.as_secs_f64();
        let [fp, uni, nest] = st.bytes_in_use_split();
        println!(
            "{}  [{:.0} tok/s, pool {:.1} KiB (fp {:.1} / uni {:.1} / nest {:.1}), \
             hit rate {:.2}]",
            r.report(),
            tok_s,
            st.bytes_in_use as f64 / 1024.0,
            fp as f64 / 1024.0,
            uni as f64 / 1024.0,
            nest as f64 / 1024.0,
            st.prefix_hit_rate()
        );
        suite.push(
            &r,
            &[
                ("variant", vi as f64),
                ("tok_s", tok_s),
                ("pool_bytes", st.bytes_in_use as f64),
                ("bytes_fp", fp as f64),
                ("bytes_uniform", uni as f64),
                ("bytes_nested", nest as f64),
                ("hit_rate", st.prefix_hit_rate()),
            ],
        );
    }
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .join("BENCH_kvmix.json");
    match suite.write_json(&json_path) {
        Ok(()) => println!("wrote {} ({} records)", json_path.display(), suite.len()),
        Err(e) => eprintln!("could not write {}: {e}", json_path.display()),
    }
}
