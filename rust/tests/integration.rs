//! Cross-layer integration tests: AOT artifacts through PJRT vs the
//! native rust engine, end-to-end quantize→serve, and the coordinator
//! under concurrent load. Skipped gracefully when `make artifacts` has
//! not been run.

use nestquant::model::engine::{Engine, EngineOptions, Method, Regime};
use nestquant::model::weights::{artifact_path, ModelWeights};
#[cfg(feature = "xla")]
use nestquant::runtime::{ModelRunner, Runtime};
use std::path::PathBuf;

/// Per-thread allocation counter wrapping the system allocator, so the
/// zero-allocation guarantees of the KV decode hot paths are *tested*
/// rather than asserted in comments. Thread-local counting keeps the
/// test immune to allocations from concurrently running tests.
mod alloc_counter {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    pub fn thread_allocs() -> u64 {
        THREAD_ALLOCS.with(|c| c.get())
    }

    pub struct CountingAlloc;

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, l: Layout) -> *mut u8 {
            let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.alloc(l)
        }
        unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
            System.dealloc(p, l)
        }
        unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
            let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
            System.realloc(p, l, n)
        }
    }
}

#[global_allocator]
static ALLOC: alloc_counter::CountingAlloc = alloc_counter::CountingAlloc;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load(name: &str) -> Option<ModelWeights> {
    let p = artifact_path(&artifacts_dir(), name);
    if !p.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ModelWeights::load(&p).unwrap())
}

#[cfg(feature = "xla")]
#[test]
fn hlo_forward_matches_native() {
    let Some(w) = load("tiny") else { return };
    let runner = ModelRunner::load(&artifacts_dir(), "tiny", 1, &w).unwrap();
    let toks: Vec<i32> = w.val_tokens[..w.cfg.ctx].to_vec();
    let hlo = runner.forward(&toks).unwrap();
    let native = nestquant::model::forward::forward_window(&w, &toks);
    assert_eq!(hlo.len(), native.data.len());
    for (i, (a, b)) in hlo.iter().zip(&native.data).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
            "logit {i}: hlo {a} vs native {b}"
        );
    }
}

#[cfg(feature = "xla")]
#[test]
fn hlo_batched_scoring_matches_native_nll() {
    let Some(w) = load("tiny") else { return };
    let runner = ModelRunner::load(&artifacts_dir(), "tiny", 4, &w).unwrap();
    let win = w.cfg.ctx;
    let mut tokens_in = Vec::new();
    let mut targets = Vec::new();
    for b in 0..4 {
        let chunk = &w.val_tokens[b * (win + 1)..(b + 1) * (win + 1)];
        tokens_in.extend_from_slice(&chunk[..win]);
        targets.extend_from_slice(&chunk[1..]);
    }
    let logits = runner.forward(&tokens_in).unwrap();
    let nlls = runner.batch_nll(&tokens_in, &targets, &logits);
    for (b, nll) in nlls.iter().enumerate() {
        let native =
            nestquant::model::forward::forward_window(&w, &tokens_in[b * win..(b + 1) * win]);
        let expect = nestquant::model::forward::window_nll(
            &native,
            &targets[b * win..(b + 1) * win],
        );
        assert!(
            (nll - expect).abs() < 1e-3,
            "window {b}: hlo nll {nll} vs native {expect}"
        );
    }
}

#[cfg(feature = "xla")]
#[test]
fn pallas_qmatmul_artifact_matches_rust_decoder() {
    use nestquant::io::tensorfile::{find, read_tensors, TensorData};
    let dir = artifacts_dir();
    let demo_path = dir.join("qmatmul_demo.nqt");
    if !demo_path.exists() {
        eprintln!("skipping: artifacts missing");
        return;
    }
    let demo = read_tensors(&demo_path).unwrap();
    let codes_t = find(&demo, "codes").unwrap();
    let (rows, cols) = (codes_t.dims[0], codes_t.dims[1]);
    let codes: Vec<i32> = match &codes_t.data {
        TensorData::I32(v) => v.clone(),
        _ => panic!(),
    };
    let beta_idx: Vec<i32> = match &find(&demo, "beta_idx").unwrap().data {
        TensorData::I32(v) => v.clone(),
        _ => panic!(),
    };
    let scales = find(&demo, "scales").unwrap().as_f32().unwrap().to_vec();
    let betas = find(&demo, "betas").unwrap().as_f32().unwrap().to_vec();
    let x = nestquant::util::Rng::new(99).gauss_vec(cols);

    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&dir.join("qmatmul_demo.hlo.txt")).unwrap();
    let lits = vec![
        rt.lit_i32(&codes, &[rows, cols]).unwrap(),
        rt.lit_i32(&beta_idx, &[rows, cols / 8]).unwrap(),
        rt.lit_f32(&scales, &[rows]).unwrap(),
        rt.lit_f32(&x, &[cols]).unwrap(),
    ];
    let y_pallas = exe.run(&lits).unwrap();

    let nq = nestquant::lattice::nested::NestedLatticeQuantizer::new_m(14, betas);
    let qm = nestquant::quant::matrix::QuantizedMatrix {
        rows,
        cols,
        q: 14,
        codes: codes.iter().map(|&c| c as u8).collect(),
        beta_idx: beta_idx.iter().map(|&b| b as u8).collect(),
        scales,
    };
    let y_rust = qm.qgemv(&nq, &x);
    for (i, (a, b)) in y_pallas.iter().zip(&y_rust).enumerate() {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "row {i}: {a} vs {b}");
    }
}

#[test]
fn quantized_engine_end_to_end_regression() {
    // The headline claim at repo scale: at 4 bits full quantization,
    // NestQuant's ppl gap to fp32 is smaller than plain uniform RTN's.
    let Some(w) = load("tiny") else { return };
    let fp = nestquant::model::forward::eval_ppl(&w, &w.val_tokens, 6);
    let nest = Engine::build(
        &w,
        EngineOptions {
            method: Method::NestQuant,
            regime: Regime::WKvA,
            calib_windows: 2,
            ..Default::default()
        },
    )
    .eval_ppl(&w.val_tokens, 6);
    let rtn = Engine::build(
        &w,
        EngineOptions {
            method: Method::Rtn,
            regime: Regime::WKvA,
            calib_windows: 2,
            ..Default::default()
        },
    )
    .eval_ppl(&w.val_tokens, 6);
    assert!(nest - fp < rtn - fp, "gap: nest {} vs rtn {}", nest - fp, rtn - fp);
}

#[test]
fn integer_gemm_backend_end_to_end() {
    // the M-variant engine must serve its forward through the packed
    // integer GEMM (prefill) / integer GEMV (decode) and stay consistent
    // with the fake-quant fp32 execution of the identical codes, through
    // full-window eval AND incremental generation.
    let Some(w) = load("tiny") else { return };
    let base = EngineOptions {
        method: Method::NestQuantM,
        regime: Regime::W,
        calib_windows: 2,
        ..Default::default()
    };
    let int_eng = Engine::build(&w, base.clone());
    assert!(
        int_eng.layers.iter().all(|l| l.wq.packed.is_some()
            && l.wk.packed.is_some()
            && l.wv.packed.is_some()
            && l.wo.packed.is_some()
            && l.w_up.packed.is_some()
            && l.w_down.packed.is_some()),
        "integer backend not wired on every linear"
    );
    let fake_eng = Engine::build(&w, EngineOptions { int_gemm: false, ..base });
    let toks: Vec<i32> = w.val_tokens[..w.cfg.ctx].to_vec();
    let a = int_eng.forward_window(&toks);
    let b = fake_eng.forward_window(&toks);
    for i in 0..a.data.len() {
        assert!(
            (a.data[i] - b.data[i]).abs() < 1e-2 * (1.0 + b.data[i].abs()),
            "prefill logits diverge at {i}: {} vs {}",
            a.data[i],
            b.data[i]
        );
    }
    // incremental decode path (integer GEMV per step): compare per-step
    // logits within tolerance — NOT argmax tokens, which can legitimately
    // flip when the top-2 logits sit closer than the numerical gap
    // between the two backends
    let mut s_int = nestquant::coordinator::generator::GenSession::new(&int_eng);
    let mut s_fake = nestquant::coordinator::generator::GenSession::new(&fake_eng);
    let prompt: Vec<i32> = w.val_tokens[..8].to_vec();
    for &tok in &prompt {
        let li = s_int.step(tok);
        let lf = s_fake.step(tok);
        for v in 0..li.len() {
            assert!(
                (li[v] - lf[v]).abs() < 1e-2 * (1.0 + lf[v].abs()),
                "decode-step logits diverge at vocab {v}: {} vs {}",
                li[v],
                lf[v]
            );
        }
    }
    // and the integer path generates to completion
    let out_int = s_int.generate(&[], 16);
    assert_eq!(out_int.len(), 16);
}

#[test]
fn kv_decode_hot_paths_are_allocation_free_for_every_lane_codec() {
    // Acceptance criterion: a decode step performs zero per-position
    // heap allocation on the scores AND value paths, for ALL THREE lane
    // codecs (fp32 copy, branch-free uniform decode, integer nested
    // decode). After one warm-up call (which sizes the caller-owned
    // score buffer), repeated streaming score / weighted-value-sum
    // calls over the heterogeneous paged store must not touch the
    // allocator at all.
    use nestquant::kvpool::{KvLaneCodec, KvPool, PoolConfig, SessionKv};
    use nestquant::lattice::nested::NestedLatticeQuantizer;
    use std::sync::Arc;
    let nq = NestedLatticeQuantizer::new_m(14, vec![0.25, 0.32, 0.45, 1.0]);
    let lanes = vec![
        KvLaneCodec::Fp32,
        KvLaneCodec::Uniform(4),
        KvLaneCodec::Nested { k: nq.clone(), v: nq },
    ];
    let pool = Arc::new(KvPool::new(3, 2, lanes, PoolConfig::default()));
    let mut cache = SessionKv::new(pool);
    let mut rng = nestquant::util::Rng::new(0xA110C);
    let dh = 32;
    for _ in 0..40 {
        let k = rng.gauss_vec(dh);
        let v = rng.gauss_vec(dh);
        for l in 0..3 {
            for h in 0..2 {
                cache.append(l, h, &k, &v);
            }
        }
    }
    let q = rng.gauss_vec(dh);
    let probs = vec![1.0 / 40.0; 40];
    let mut scores = Vec::new();
    let mut wsum = vec![0f32; dh];
    // warm-up: grows `scores` to capacity once
    cache.scores(0, 1, &q, &mut scores);
    cache.weighted_value_sum(0, 1, &probs, &mut wsum);
    let before = alloc_counter::thread_allocs();
    for _ in 0..5 {
        for l in 0..3 {
            cache.scores(l, 1, &q, &mut scores);
            cache.weighted_value_sum(l, 1, &probs, &mut wsum);
            cache.scores(l, 0, &q, &mut scores);
            cache.weighted_value_sum(l, 0, &probs, &mut wsum);
        }
    }
    let after = alloc_counter::thread_allocs();
    assert_eq!(scores.len(), 40);
    assert_eq!(
        after, before,
        "decode hot paths allocated {} time(s)",
        after - before
    );
}

#[test]
fn fused_decode_hot_loop_is_allocation_free_for_every_lane_codec() {
    // The fused scheduler's acceptance criterion: after a warm-up that
    // sizes every scratch buffer (StepScratch mats, per-linear GEMM
    // scratch, score buffer, token history, page 0 of each session),
    // `Engine::forward_step_fused` over a 3-session batch must perform
    // zero heap allocations per token — for all three KV lane codecs.
    // The measured steps stay inside one 16-token page, since crossing a
    // page boundary legitimately claims a fresh page.
    //
    // The measured window runs WITH tracing enabled (pool journal
    // attached, every step sampled): per-site GEMM spans cost clock
    // reads and fixed-size ring pushes only, so the hot loop must stay
    // allocation-free with instrumentation compiled in and active.
    use nestquant::kvpool::{KvLaneCodec, PoolConfig, SessionKv};
    use nestquant::model::engine::StepScratch;
    use nestquant::util::linalg::Mat;
    let cfg = nestquant::model::ModelConfig {
        vocab: 48,
        ctx: 64,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
    };
    let w = ModelWeights::synthetic(cfg, 0xA110C2);
    let cases = [
        (
            "fp32",
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::W,
                calib_windows: 1,
                ..Default::default()
            },
        ),
        (
            "uniform",
            EngineOptions {
                method: Method::UniformRot,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ),
        (
            "nested",
            EngineOptions {
                method: Method::NestQuantM,
                regime: Regime::WKv,
                calib_windows: 1,
                ..Default::default()
            },
        ),
    ];
    for (name, opts) in cases {
        let eng = Engine::build(&w, opts);
        match name {
            "fp32" => assert!(matches!(eng.layers[0].kv, KvLaneCodec::Fp32)),
            "uniform" => assert!(matches!(eng.layers[0].kv, KvLaneCodec::Uniform(_))),
            _ => assert!(matches!(eng.layers[0].kv, KvLaneCodec::Nested { .. })),
        }
        let pool = eng.kv_pool(PoolConfig::default()); // 16-token pages
        let trace = std::sync::Arc::new(nestquant::obs::Trace::manual(2048));
        pool.set_trace(trace.clone());
        let mut s0 = SessionKv::new(pool.clone());
        let mut s1 = SessionKv::new(pool.clone());
        let mut s2 = SessionKv::new(pool);
        for s in [&mut s0, &mut s1, &mut s2] {
            s.reserve_tokens(cfg.ctx);
        }
        let mut caches: Vec<&mut SessionKv> = vec![&mut s0, &mut s1, &mut s2];
        let mut scratch = StepScratch::new();
        let mut logits = Mat::zeros(0, 0);
        let mut tokens = [0i32; 3];
        let mut positions = [0usize; 3];
        // warm-up: sizes every scratch buffer, claims page 0 per session
        for it in 0..6usize {
            for (s, t) in tokens.iter_mut().enumerate() {
                *t = ((it * 7 + s * 3 + 1) % 48) as i32;
            }
            eng.forward_step_fused(&tokens, &positions, &mut caches, &mut scratch, &mut logits);
            for p in positions.iter_mut() {
                *p += 1;
            }
        }
        let before = alloc_counter::thread_allocs();
        for it in 6..14usize {
            for (s, t) in tokens.iter_mut().enumerate() {
                *t = ((it * 5 + s * 2 + 3) % 48) as i32;
            }
            eng.forward_step_fused_traced(
                &tokens,
                &positions,
                &mut caches,
                &mut scratch,
                &mut logits,
                Some(&*trace),
            );
            for p in positions.iter_mut() {
                *p += 1;
            }
        }
        let after = alloc_counter::thread_allocs();
        assert_eq!(logits.rows, 3);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        assert_eq!(
            after,
            before,
            "{name}: fused decode hot loop allocated {} time(s)",
            after - before
        );
        // the instrumentation was really live: 8 traced steps × (2
        // layers × 6 linears + lm head) GEMM spans landed in the ring
        let spans = trace
            .snapshot()
            .iter()
            .filter(|e| matches!(e.kind, nestquant::obs::EventKind::SiteGemm { .. }))
            .count();
        assert_eq!(spans, 8 * 13, "{name}: missing site_gemm spans");
        assert_eq!(trace.dropped(), 0, "{name}: trace ring overflowed");
    }
}

#[test]
fn lut_backend_fused_decode_hot_loop_is_allocation_free() {
    // The LUT backend's zero-alloc acceptance: a fused decode step
    // through hierarchical-LUT weight sites (activation encode + pure
    // table-lookup GEMV inside `quant::lut`, no decoded rows ever
    // materialized) must not touch the allocator after one warm-up that
    // sizes the LutScratch index/β/scale buffers — with tracing live,
    // so the `site_gemm` spans also prove every weight site really
    // served through the LUT path.
    use nestquant::kvpool::{PoolConfig, SessionKv};
    use nestquant::model::engine::StepScratch;
    use nestquant::obs::GemmPath;
    use nestquant::quant::plan::{EngineBuilder, GemmBackend, PolicyPatch, SiteRole, SiteSelector};
    use nestquant::util::linalg::Mat;
    let cfg = nestquant::model::ModelConfig {
        vocab: 48,
        ctx: 64,
        d_model: 32,
        n_layer: 2,
        n_head: 2,
        d_ff: 64,
    };
    let w = ModelWeights::synthetic(cfg, 0xA110C3);
    let eng = EngineBuilder::from_options(EngineOptions {
        method: Method::NestQuantM,
        regime: Regime::W,
        calib_windows: 1,
        ..Default::default()
    })
    .rule(
        SiteSelector {
            role: Some(SiteRole::Weights),
            ..Default::default()
        },
        PolicyPatch {
            backend: Some(GemmBackend::Lut),
            q: Some(2),
            m_levels: Some(4),
            ..Default::default()
        },
    )
    .build(&w);
    assert!(
        eng.layers.iter().all(|l| {
            l.wq.lut.is_some()
                && l.wk.lut.is_some()
                && l.wv.lut.is_some()
                && l.wo.lut.is_some()
                && l.w_up.lut.is_some()
                && l.w_down.lut.is_some()
        }) && eng.head.lut.is_some(),
        "LUT backend not wired on every weight site"
    );
    let pool = eng.kv_pool(PoolConfig::default());
    let trace = std::sync::Arc::new(nestquant::obs::Trace::manual(2048));
    pool.set_trace(trace.clone());
    let mut s0 = SessionKv::new(pool.clone());
    let mut s1 = SessionKv::new(pool.clone());
    let mut s2 = SessionKv::new(pool);
    for s in [&mut s0, &mut s1, &mut s2] {
        s.reserve_tokens(cfg.ctx);
    }
    let mut caches: Vec<&mut SessionKv> = vec![&mut s0, &mut s1, &mut s2];
    let mut scratch = StepScratch::new();
    let mut logits = Mat::zeros(0, 0);
    let mut tokens = [0i32; 3];
    let mut positions = [0usize; 3];
    for it in 0..6usize {
        for (s, t) in tokens.iter_mut().enumerate() {
            *t = ((it * 7 + s * 3 + 1) % 48) as i32;
        }
        eng.forward_step_fused(&tokens, &positions, &mut caches, &mut scratch, &mut logits);
        for p in positions.iter_mut() {
            *p += 1;
        }
    }
    let before = alloc_counter::thread_allocs();
    for it in 6..14usize {
        for (s, t) in tokens.iter_mut().enumerate() {
            *t = ((it * 5 + s * 2 + 3) % 48) as i32;
        }
        eng.forward_step_fused_traced(
            &tokens,
            &positions,
            &mut caches,
            &mut scratch,
            &mut logits,
            Some(&*trace),
        );
        for p in positions.iter_mut() {
            *p += 1;
        }
    }
    let after = alloc_counter::thread_allocs();
    assert_eq!(logits.rows, 3);
    assert!(logits.data.iter().all(|v| v.is_finite()));
    assert_eq!(
        after,
        before,
        "LUT fused decode hot loop allocated {} time(s)",
        after - before
    );
    // every span of the 8 traced steps × 13 weight sites must be
    // attributed to the LUT backend
    let (mut lut_spans, mut other_spans) = (0usize, 0usize);
    for e in trace.snapshot() {
        if let nestquant::obs::EventKind::SiteGemm { backend, .. } = e.kind {
            if backend == GemmPath::Lut {
                lut_spans += 1;
            } else {
                other_spans += 1;
            }
        }
    }
    assert_eq!(lut_spans, 8 * 13, "missing LUT-attributed site_gemm spans");
    assert_eq!(other_spans, 0, "a weight site served off the LUT path");
    assert_eq!(trace.dropped(), 0, "trace ring overflowed");
}

#[test]
fn trace_smoke_soak_exports_perfetto_and_prometheus() {
    // The `make trace-smoke` gate: a multi-session soak through the
    // full server with every decode step traced must export (a) a
    // Chrome trace-event JSON journal that shape-validates for
    // Perfetto and covers every track category, and (b) a Prometheus
    // text snapshot that parses with every latency family present.
    // Synthetic weights — runs without `make artifacts`.
    use nestquant::coordinator::{BatchPolicy, Request, Server, ServerConfig};
    use nestquant::obs::TraceConfig;
    let w = ModelWeights::synthetic(
        nestquant::model::ModelConfig {
            vocab: 48,
            ctx: 64,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        },
        0x7AACE,
    );
    let eng = std::sync::Arc::new(Engine::build(
        &w,
        EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        },
    ));
    let (srv, rx) = Server::start(
        eng,
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
            },
            trace: TraceConfig {
                capacity: 8192,
                sample_every: 1,
            },
            ..ServerConfig::default()
        },
    );
    let common: Vec<i32> = (0..16).map(|i| (i * 5 + 3) % 48).collect();
    let n = 6u64;
    for id in 0..n {
        let mut prompt = common.clone();
        prompt.push(30 + id as i32);
        srv.submit(Request::Generate { id, prompt, n_new: 4 }).unwrap();
    }
    for _ in 0..n {
        let r = rx.recv_timeout(std::time::Duration::from_secs(300)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 4);
    }
    let trace = srv.trace.clone();
    let metrics = srv.metrics.clone();
    assert!(srv.shutdown().drained);

    let events = trace.snapshot();
    assert!(!events.is_empty());
    for cat in ["request", "engine", "kvpool", "worker"] {
        assert!(
            events.iter().any(|e| e.kind.category() == cat),
            "journal has no {cat} events"
        );
    }
    let json = nestquant::obs::chrome_trace_json(&events);
    nestquant::obs::validate_chrome_trace(&json).unwrap();

    let prom = metrics.prometheus_text();
    nestquant::obs::validate_prometheus(&prom).unwrap();
    for family in [
        "nestquant_requests_total",
        "nestquant_queue_wait_seconds_bucket",
        "nestquant_ttft_seconds_count",
        "nestquant_inter_token_seconds_sum",
        "nestquant_prefill_seconds_count",
        "nestquant_fused_step_seconds_bucket",
    ] {
        assert!(prom.contains(family), "prometheus snapshot missing {family}");
    }
    // one TTFT sample per request, and bounded journal memory
    assert_eq!(metrics.ttft_summary().count, n);
    assert_eq!(trace.dropped(), 0);
}

#[test]
fn mixed_kv_plan_eval_and_serve_are_consistent() {
    // Acceptance criterion: a plan mixing Fp32, Uniform and Nested KV
    // layers runs end-to-end through the (now total) paged pool, and
    // the serving path applies exactly the per-layer roundtrips that
    // batch eval applies. The KV payloads both paths consume are
    // bitwise identical (the pool decodes to the same bits as
    // `KvLaneCodec::roundtrip_*` — asserted per layer below and in
    // `kvpool`'s lane-parity test); the logits agree to the same
    // float-accumulation tolerance as the all-fp incremental-vs-window
    // test, which the pre-refactor fp-everywhere fallback failed by
    // construction for such plans.
    use nestquant::kvpool::{KvLaneCodec, PoolConfig};
    use nestquant::quant::plan::{EngineBuilder, PolicyPatch, SiteRole, SiteSelector};
    let w = ModelWeights::synthetic(
        nestquant::model::ModelConfig {
            vocab: 48,
            ctx: 48,
            d_model: 32,
            n_layer: 3,
            n_head: 2,
            d_ff: 64,
        },
        0x3A2E,
    );
    let eng = EngineBuilder::from_options(EngineOptions {
        method: Method::NestQuantM,
        regime: Regime::WKv,
        calib_windows: 1,
        ..Default::default()
    })
    .rule(
        SiteSelector {
            layers: Some((0, 0)),
            role: Some(SiteRole::Kv),
            ..Default::default()
        },
        PolicyPatch::fp(),
    )
    .rule(
        SiteSelector {
            layers: Some((1, 1)),
            role: Some(SiteRole::Kv),
            ..Default::default()
        },
        PolicyPatch {
            method: Some(Method::UniformRot),
            ..Default::default()
        },
    )
    .build(&w);
    assert!(matches!(eng.layers[0].kv, KvLaneCodec::Fp32));
    assert!(matches!(eng.layers[1].kv, KvLaneCodec::Uniform(_)));
    assert!(matches!(eng.layers[2].kv, KvLaneCodec::Nested { .. }));
    // the pool is total: every lane codec matches the engine's
    let pool = eng.kv_pool(PoolConfig::default());
    for l in 0..3 {
        assert_eq!(pool.lane(l).is_fp(), eng.layers[l].kv.is_fp());
    }
    // serve (incremental, through the heterogeneous pool) vs eval
    // (forward_window fake-quant roundtrips): step-by-step logits
    let toks: Vec<i32> = (0..24).map(|i| (i * 7 + 3) % 48).collect();
    let full = eng.forward_window(&toks);
    let mut sess = nestquant::coordinator::generator::GenSession::new_in_pool(&eng, &pool);
    for (t, &tok) in toks.iter().enumerate() {
        let logits = sess.step(tok);
        for v in 0..w.cfg.vocab {
            assert!(
                (logits[v] - full[(t, v)]).abs() < 2e-3 * (1.0 + full[(t, v)].abs()),
                "t={t} v={v}: serve {} vs eval {}",
                logits[v],
                full[(t, v)]
            );
        }
    }
    let st = pool.stats();
    assert!(st.pages_in_use > 0);
    assert!(
        st.page_bytes_fp > 0 && st.page_bytes_uniform > 0 && st.page_bytes_nested > 0,
        "mixed page must account every lane class: {st:?}"
    );
}

#[test]
fn budget_constrained_pool_keeps_live_sessions_bit_identical() {
    // Eviction acceptance: a pool under byte-budget pressure (forced to
    // evict a finished session's cached prefix run) must produce logits
    // bit-identical to an unbounded pool for the live session.
    use nestquant::coordinator::generator::GenSession;
    use nestquant::kvpool::PoolConfig;
    let w = ModelWeights::synthetic(
        nestquant::model::ModelConfig {
            vocab: 48,
            ctx: 64,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        },
        0xE71C,
    );
    let eng = Engine::build(
        &w,
        EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        },
    );
    let prompt_a: Vec<i32> = (0..33).map(|i| i % 48).collect();
    let prompt_b: Vec<i32> = (0..33).map(|i| (i * 5 + 7) % 48).collect();

    // reference: unbounded pool, session B alone
    let ref_pool = eng.kv_pool(PoolConfig::default());
    let ref_logits = GenSession::new_in_pool(&eng, &ref_pool).prefill(&prompt_b);

    // learn the page byte cost, then budget exactly 3 pages
    let bpp = ref_pool.stats().bytes_per_page;
    assert!(bpp > 0);
    let pool = eng.kv_pool(PoolConfig {
        page_size: 16,
        budget_bytes: Some(3 * bpp),
    });
    {
        let mut a = GenSession::new_in_pool(&eng, &pool);
        a.prefill(&prompt_a);
    } // A finishes; its frozen pages stay cached in the prefix index
    let mut b = GenSession::new_in_pool(&eng, &pool);
    let logits = b.prefill(&prompt_b);
    let st = pool.stats();
    assert!(st.evicted_pages > 0, "budget must have forced eviction: {st:?}");
    assert!(
        st.bytes_in_use <= 3 * bpp,
        "budget exceeded with reclaimable pages present: {st:?}"
    );
    assert_eq!(logits.len(), ref_logits.len());
    for (i, (x, y)) in logits.iter().zip(&ref_logits).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "eviction changed live-session logits at {i}: {x} vs {y}"
        );
    }
}

#[test]
fn mixed_precision_plan_serves_end_to_end() {
    // A non-uniform QuantPlan (fp lm_head, q=16 down, q=12 elsewhere,
    // nested KV) must serve through the full coordinator stack and
    // surface its per-site payload split in Metrics.
    use nestquant::quant::plan::{EngineBuilder, PolicyPatch, SiteKind};
    let w = ModelWeights::synthetic(
        nestquant::model::ModelConfig {
            vocab: 48,
            ctx: 64,
            d_model: 32,
            n_layer: 2,
            n_head: 2,
            d_ff: 64,
        },
        0x91AC,
    );
    let eng = std::sync::Arc::new(
        EngineBuilder::from_options(EngineOptions {
            method: Method::NestQuantM,
            regime: Regime::WKv,
            q: 12,
            calib_windows: 1,
            ..Default::default()
        })
        .site(SiteKind::Down, PolicyPatch::rate(16))
        .site(SiteKind::LmHead, PolicyPatch::fp())
        .build(&w),
    );
    let (srv, rx) = nestquant::coordinator::Server::start(
        eng,
        nestquant::coordinator::ServerConfig::default(),
    );
    let common: Vec<i32> = (0..24).map(|i| (i * 5 + 3) % 48).collect();
    for id in 0..2u64 {
        let mut prompt = common.clone();
        prompt.push(40 + id as i32);
        srv.submit(nestquant::coordinator::Request::Generate {
            id,
            prompt,
            n_new: 4,
        })
        .unwrap();
    }
    for _ in 0..2 {
        let r = rx
            .recv_timeout(std::time::Duration::from_secs(120))
            .unwrap();
        assert_eq!(r.tokens.len(), 4);
    }
    // per-site gauges: 2 layers × 6 linears + the head, fp head included
    let sites = srv.metrics.weight_sites();
    assert_eq!(sites.len(), 13);
    let head = sites.iter().find(|(l, _)| l == "lm_head.weights").unwrap();
    let down = sites.iter().find(|(l, _)| l == "L0.down.weights").unwrap();
    assert!(head.1 > down.1, "fp head must dominate coded sites: {sites:?}");
    assert!(srv.metrics.report().contains("weights: sites=13 quantized=12"));
    srv.shutdown();
}

#[test]
fn coordinator_concurrent_load() {
    let Some(w) = load("tiny") else { return };
    let eng = std::sync::Arc::new(Engine::build(
        &w,
        EngineOptions {
            regime: Regime::WKv,
            calib_windows: 1,
            ..Default::default()
        },
    ));
    let (srv, rx) = nestquant::coordinator::Server::start(
        eng,
        nestquant::coordinator::ServerConfig::default(),
    );
    let n = 6;
    for i in 0..n {
        srv.submit(nestquant::coordinator::Request::Generate {
            id: i,
            prompt: w.val_tokens[..8].to_vec(),
            n_new: 6,
        })
        .unwrap();
    }
    let mut seen = std::collections::HashSet::new();
    for _ in 0..n {
        let r = rx.recv_timeout(std::time::Duration::from_secs(300)).unwrap();
        assert_eq!(r.tokens.len(), 6);
        seen.insert(r.id);
    }
    assert_eq!(seen.len(), n as usize);
    assert!(srv.metrics.throughput_tok_s() > 0.0);
    srv.shutdown();
}

/// The forced-dispatch contract end to end: `kernels::active()` honors
/// `NESTQUANT_KERNEL` when the requested tier runs on this host (and
/// falls back to the best detected tier otherwise), and whatever tier
/// it picks, the dispatched GEMM paths of every quantized backend stay
/// bitwise identical to the forced-scalar GEMV reference. `make
/// test-kernels` runs the suite once per tier with the env var pinned,
/// so each tier's branch of this test executes in its own process — no
/// `set_var` racing inside one.
#[test]
fn kernel_dispatch_honors_env_and_stays_bitexact() {
    use nestquant::lattice::hierarchical::HierarchicalQuantizer;
    use nestquant::lattice::nested::NestedLatticeQuantizer;
    use nestquant::quant::gemm::GemmScratch;
    use nestquant::quant::kernels::{self, Kernel};
    use nestquant::quant::lut::{LutScratch, PackedLutMatrix};
    use nestquant::quant::qgemm::PackedNestMatrix;
    use nestquant::util::linalg::Mat;
    use nestquant::util::Rng;

    let active = kernels::active();
    assert!(
        active.supported(),
        "dispatch picked a tier this host cannot run: {active:?}"
    );
    if let Ok(v) = std::env::var(kernels::ENV_KERNEL) {
        match Kernel::parse(&v) {
            Some(req) if req.supported() => assert_eq!(
                active, req,
                "{}={v} was set and supported but not honored",
                kernels::ENV_KERNEL
            ),
            // unsupported/unknown requests fall back to detection; the
            // supported() assert above already pins the outcome
            _ => {}
        }
    }

    let mut rng = Rng::new(0xD15B);
    let (rows, cols, batch) = (9usize, 64usize, 13usize);
    let w = Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols));
    let xt = Mat::from_vec(batch, cols, rng.gauss_vec(batch * cols));
    let betas = vec![0.25f32, 0.32, 0.45, 1.0];

    // packed coset backend: dispatched gemm vs forced-scalar gemv
    let nq = NestedLatticeQuantizer::new_m(14, betas.clone());
    let packed = PackedNestMatrix::quantize(&w, &nq);
    let mut yt = Mat::zeros(batch, rows);
    let mut scratch = GemmScratch::new();
    packed.gemm_into(&xt, &mut yt, 2, &mut scratch);
    let mut yref = vec![0f32; rows];
    for c in 0..batch {
        packed.gemv_into_with(Kernel::Scalar, xt.row(c), &mut yref);
        for r in 0..rows {
            assert_eq!(
                yt.row(c)[r].to_bits(),
                yref[r].to_bits(),
                "packed backend col {c} row {r}: dispatched {:?} != scalar",
                active
            );
        }
    }

    // LUT backend: dispatched gemm vs forced-scalar gemv
    let wq = HierarchicalQuantizer::new(2, 3, betas.clone());
    let aq = HierarchicalQuantizer::new(2, 3, betas);
    let lut = PackedLutMatrix::from_quantized(&wq.quantize_matrix(&w), &wq, aq);
    let mut lscratch = LutScratch::new();
    let mut yt = Mat::zeros(batch, rows);
    lut.gemm_into(&xt, &mut yt, 2, &mut lscratch);
    for c in 0..batch {
        lut.gemv_into_with(Kernel::Scalar, xt.row(c), &mut yref, &mut lscratch);
        for r in 0..rows {
            assert_eq!(
                yt.row(c)[r].to_bits(),
                yref[r].to_bits(),
                "lut backend col {c} row {r}: dispatched {:?} != scalar",
                active
            );
        }
    }
}
